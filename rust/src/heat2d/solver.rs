//! Executable heat-2D solver on the unified exchange runtime (Listings 7 &
//! 8), validated against a sequential reference.
//!
//! The halo pattern is compiled **once** from the grid into a
//! [`StridedPlan`] — vertical halos as contiguous row strips (the
//! `upc_memget` of Listing 7), horizontal halos as strided columns (the
//! pack/unpack scratch arrays) — and every time step replays it through the
//! [`ExchangeRuntime`]'s persistent staging arena and worker pool. A
//! steady-state step allocates nothing and spawns nothing on either engine.

use crate::comm::{ComputeSplit, StridedBlock, StridedPlan};
use crate::engine::{
    check_depth, check_generation, check_plan_hash, kernels, tree_fold, Checkpoint, Engine,
    ExchangeRuntime, ReduceOp, ReductionPlan,
};
use crate::model::HeatGrid;

/// Compile the grid's halo exchange into a strided block-copy plan.
///
/// Per thread, in the legacy unpack order (left, right, up, down):
/// neighbours' boundary interior columns/rows → this thread's halo
/// column/row. Column strips are strided (`col_stride = n`), row strips
/// contiguous — exactly the shapes eq. (19) charges pack time for.
pub(crate) fn halo_plan(grid: &HeatGrid) -> StridedPlan {
    let (m, n) = grid.subdomain();
    let mut copies = Vec::new();
    for t in 0..grid.threads() {
        let (ip, kp) = grid.coords(t);
        // Left neighbour's last interior column → my col 0.
        if kp > 0 {
            copies.push((
                grid.rank(ip, kp - 1),
                t,
                StridedBlock::column(n + (n - 2), m - 2, n),
                StridedBlock::column(n, m - 2, n),
            ));
        }
        // Right neighbour's first interior column → my col n−1.
        if kp < grid.nprocs - 1 {
            copies.push((
                grid.rank(ip, kp + 1),
                t,
                StridedBlock::column(n + 1, m - 2, n),
                StridedBlock::column(n + (n - 1), m - 2, n),
            ));
        }
        // Upper neighbour's last interior row → my row 0 (contiguous).
        if ip > 0 {
            copies.push((
                grid.rank(ip - 1, kp),
                t,
                StridedBlock::row((m - 2) * n + 1, n - 2),
                StridedBlock::row(1, n - 2),
            ));
        }
        // Lower neighbour's first interior row → my row m−1.
        if ip < grid.mprocs - 1 {
            copies.push((
                grid.rank(ip + 1, kp),
                t,
                StridedBlock::row(n + 1, n - 2),
                StridedBlock::row((m - 1) * n + 1, n - 2),
            ));
        }
    }
    let plan = StridedPlan::from_msgs(grid.threads(), &copies);
    debug_assert!(plan.validate(&|_| m * n).is_ok());
    plan
}

/// Compile the interior/boundary decomposition for the overlapped step and
/// validate it (debug builds) against the canonical owned region.
pub(crate) fn compute_split(grid: &HeatGrid) -> ComputeSplit {
    let (m, n) = grid.subdomain();
    let split = ComputeSplit::grid2d(m, n);
    debug_assert!(
        split.validate(&ComputeSplit::owned2d(m, n), m * n).is_ok(),
        "heat2d split invalid: {:?}",
        split.validate(&ComputeSplit::owned2d(m, n), m * n)
    );
    split
}

/// Per-thread subdomain state (`phi`/`phin` of Listing 8) plus the compiled
/// exchange runtime.
#[derive(Debug)]
pub struct Heat2dSolver {
    pub grid: HeatGrid,
    /// `phi[t]` — the m×n (halo-included) field of thread t, row-major.
    phi: Vec<Vec<f64>>,
    /// New-timestep buffers (`phin` in Listing 8).
    phin: Vec<Vec<f64>>,
    /// Compiled halo plan + staging arena + persistent worker pool.
    runtime: ExchangeRuntime,
    /// Interior/boundary decomposition for the split-phase overlapped step.
    split: ComputeSplit,
    /// Halo-exchange byte counter (payload crossing thread boundaries).
    pub inter_thread_bytes: u64,
}

impl Heat2dSolver {
    /// Initialize from a global field of `m_glob × n_glob` values.
    /// Boundary values of the global domain are treated as fixed (Dirichlet).
    pub fn new(grid: HeatGrid, global: &[f64]) -> Heat2dSolver {
        let plan = halo_plan(&grid);
        Heat2dSolver::with_plan(grid, global, plan)
    }

    /// Initialize with a caller-supplied halo plan — a raw
    /// ([`refine_strided`](crate::comm::refine_strided)) or optimized
    /// ([`PlanOptimizer`](crate::comm::PlanOptimizer)) variant of
    /// `halo_plan`. The plan must carry the same cell assignments; only
    /// message granularity and arena order may differ.
    pub fn with_plan(grid: HeatGrid, global: &[f64], plan: StridedPlan) -> Heat2dSolver {
        assert_eq!(global.len(), grid.m_glob * grid.n_glob);
        let phi: Vec<Vec<f64>> =
            (0..grid.threads()).map(|t| initial_field(grid, global, t)).collect();
        let phin = phi.clone();
        let runtime = ExchangeRuntime::new(plan);
        let split = compute_split(&grid);
        Heat2dSolver { grid, phi, phin, runtime, split, inter_thread_bytes: 0 }
    }

    /// The compiled exchange runtime (plan + arena + pool).
    pub fn runtime(&self) -> &ExchangeRuntime {
        &self.runtime
    }

    /// Mutable runtime access — for configuring wait deadlines and fault
    /// plans on the underlying pool.
    pub fn runtime_mut(&mut self) -> &mut ExchangeRuntime {
        &mut self.runtime
    }

    /// Structural fingerprint of the compiled halo plan (stamped into
    /// checkpoints).
    pub fn plan_fingerprint(&self) -> u64 {
        self.runtime.plan_fingerprint()
    }

    /// Snapshot the solver between batches: both field buffers, the byte
    /// counter, and the plan fingerprint. `step` is caller-stamped (steps
    /// completed so far, by the caller's own count).
    pub fn checkpoint(&self, step: u64) -> Checkpoint {
        Checkpoint {
            step,
            plan_hash: self.plan_fingerprint(),
            depth: self.runtime.depth(),
            generation: self.runtime.generation(),
            fields: self.phi.clone(),
            scratch: self.phin.clone(),
            inter_thread_bytes: self.inter_thread_bytes,
        }
    }

    /// Restore a snapshot taken by [`checkpoint`](Self::checkpoint).
    /// Verifies the plan fingerprint and the field shapes, then overwrites
    /// both buffers and the byte counter; returns the checkpoint's step
    /// stamp. The runtime's monotone exchange epochs are deliberately *not*
    /// reset — the pipelined ack gate skips a batch's first D epochs (the
    /// pipeline depth), so resuming is safe at any epoch.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<u64, String> {
        check_plan_hash("heat2d", self.plan_fingerprint(), ck.plan_hash)?;
        check_depth("heat2d", self.runtime.depth(), ck.depth)?;
        check_generation("heat2d", self.runtime.generation(), ck.generation)?;
        let (m, n) = self.grid.subdomain();
        if ck.fields.len() != self.grid.threads() || ck.scratch.len() != self.grid.threads() {
            return Err("heat2d checkpoint thread count mismatch".into());
        }
        if ck.fields.iter().chain(&ck.scratch).any(|f| f.len() != m * n) {
            return Err("heat2d checkpoint field shape mismatch".into());
        }
        self.phi.clone_from(&ck.fields);
        self.phin.clone_from(&ck.scratch);
        self.inter_thread_bytes = ck.inter_thread_bytes;
        Ok(ck.step)
    }

    /// Run `steps` pipelined time steps in batches of `every`, handing a
    /// checkpoint to `sink` after each batch. Bitwise identical to one
    /// [`run_pipelined_with`](Self::run_pipelined_with) call over `steps`:
    /// the pipelined protocol is itself bitwise identical to chained
    /// batches, and each batch starts from the fields the previous one
    /// left under `phi`. Checkpoints are stamped with steps completed
    /// within this call; a resuming caller offsets by its own base count.
    pub fn run_pipelined_checkpointed_with(
        &mut self,
        engine: Engine,
        steps: usize,
        every: usize,
        sink: &mut dyn FnMut(Checkpoint),
    ) {
        let every = every.max(1);
        let mut done = 0usize;
        while done < steps {
            let batch = (steps - done).min(every);
            self.run_pipelined_with(engine, batch);
            done += batch;
            sink(self.checkpoint(done as u64));
        }
    }

    /// The compiled interior/boundary decomposition.
    pub fn split(&self) -> &ComputeSplit {
        &self.split
    }

    /// Per-thread halo-extended fields (`phi`), e.g. for comparing a
    /// distributed run's rank-local results against this reference.
    pub fn local_fields(&self) -> &[Vec<f64>] {
        &self.phi
    }

    /// One time step: halo exchange then 5-point Jacobi update (on the
    /// sequential oracle engine).
    pub fn step(&mut self) {
        self.step_with(Engine::Sequential);
    }

    /// One time step on the chosen engine. Both engines replay the same
    /// compiled plan with the same pack/unpack/update code, so fields and
    /// halo byte counts are bitwise identical; [`Engine::Parallel`] runs one
    /// persistent pool worker per grid thread.
    pub fn step_with(&mut self, engine: Engine) {
        let grid = self.grid;
        self.runtime.step_strided(engine, &mut self.phi, &mut self.phin, |t, phi, phin| {
            Self::jacobi_update(grid, t, phi, phin);
        });
        self.inter_thread_bytes += self.runtime.payload_bytes();
        std::mem::swap(&mut self.phi, &mut self.phin);
    }

    /// One split-phase overlapped time step: pack + publish, interior
    /// Jacobi (overlapping the exchange), per-peer waits + unpack, boundary
    /// Jacobi + the fixed-boundary copy-through. Interior and boundary
    /// kernels run the same per-cell expression as [`Self::step_with`] over
    /// the compiled [`ComputeSplit`], so fields and byte counters stay
    /// bitwise identical to the synchronous step and the sequential oracle.
    pub fn step_overlapped_with(&mut self, engine: Engine) {
        let grid = self.grid;
        let (_, n) = grid.subdomain();
        let split = &self.split;
        self.runtime.step_overlapped(
            engine,
            &mut self.phi,
            &mut self.phin,
            |_t, phi, phin| {
                jacobi_blocks(n, &split.interior, phi, phin);
            },
            |t, phi, phin| {
                jacobi_blocks(n, &split.boundary, phi, phin);
                Self::fixed_boundary_copy(grid, t, phi, phin);
            },
        );
        self.inter_thread_bytes += self.runtime.payload_bytes();
        std::mem::swap(&mut self.phi, &mut self.phin);
    }

    /// The runtime's pipeline depth D (buffered staging slots; how far a
    /// pipelined sender may run ahead).
    pub fn depth(&self) -> usize {
        self.runtime.depth()
    }

    /// Reconfigure the pipeline depth between steps or batches
    /// ([`ExchangeRuntime::set_depth`]). Depth changes never alter results
    /// — only how much sender/receiver jitter the pipeline absorbs.
    pub fn set_depth(&mut self, depth: usize) {
        self.runtime.set_depth(depth);
    }

    /// One **fused** split-phase time step (sequential oracle engine): the
    /// column halos unpack through the plan as usual, but each up/down
    /// ghost-row message is consumed by
    /// [`kernels::fused_unpack_jacobi_row`], which writes the ghost row
    /// into `phi` *and* computes the adjacent boundary Jacobi row into
    /// `phin` in the same pass — one traversal of those rows instead of
    /// the separate unpack and boundary sweeps, while the values are hot
    /// in registers. The residual boundary cells (side columns plus any
    /// unfused rows) run through the normal block kernel, so every owned
    /// cell is still computed exactly once with the unchanged expression
    /// and the step stays **bitwise identical** to
    /// [`step_with`](Self::step_with) /
    /// [`step_overlapped_with`](Self::step_overlapped_with).
    ///
    /// Fusion is sound here because the fused row's other operands are
    /// never written by an unpack: the down-neighbour row it reads is an
    /// owned row (guaranteed by the `m ≥ 4` gate below), and its left /
    /// right ghost-column cells arrive in the column messages, which the
    /// plan orders *before* the row messages. Subdomains shorter than 4
    /// rows fall back to plain unpacking; the parallel engine has no
    /// fused arm yet (ROADMAP follow-up).
    pub fn step_fused(&mut self) {
        let grid = self.grid;
        let (m, n) = grid.subdomain();
        let split = &self.split;
        let threads = grid.threads();
        // Recv-message indices of the up/down ghost rows per thread:
        // `halo_plan` pushes left col, right col, up row, down row, and
        // `StridedPlan::from_msgs` keeps the per-receiver order, so the
        // row messages sit right after the column messages.
        let fusable = m >= 4;
        let mut up_idx = vec![usize::MAX; threads];
        let mut down_idx = vec![usize::MAX; threads];
        let mut residual: Vec<Vec<StridedBlock>> = Vec::with_capacity(threads);
        for t in 0..threads {
            let (ip, kp) = grid.coords(t);
            let cols = usize::from(kp > 0) + usize::from(kp < grid.nprocs - 1);
            let fuse_up = fusable && ip > 0;
            let fuse_down = fusable && ip < grid.mprocs - 1;
            if fuse_up {
                up_idx[t] = cols;
            }
            if fuse_down {
                down_idx[t] = cols + usize::from(ip > 0);
            }
            residual.push(residual_boundary(m, n, fuse_up, fuse_down));
        }
        self.runtime.step_overlapped_fused(
            &mut self.phi,
            &mut self.phin,
            |_t, phi, phin| jacobi_blocks(n, &split.interior, phi, phin),
            |t, i, staged, phi, phin| {
                if i == up_idx[t] {
                    // Ghost row 0 → boundary row 1 (reads owned row 2).
                    kernels::fused_unpack_jacobi_row(staged, phi, 1, n + 1, 2 * n + 1, phin);
                } else if i == down_idx[t] {
                    // Ghost row m−1 → boundary row m−2 (reads row m−3).
                    kernels::fused_unpack_jacobi_row(
                        staged,
                        phi,
                        (m - 1) * n + 1,
                        (m - 2) * n + 1,
                        (m - 3) * n + 1,
                        phin,
                    );
                } else {
                    return false;
                }
                true
            },
            |t, phi, phin| {
                jacobi_blocks(n, &residual[t], phi, phin);
                Self::fixed_boundary_copy(grid, t, phi, phin);
            },
        );
        self.inter_thread_bytes += self.runtime.payload_bytes();
        std::mem::swap(&mut self.phi, &mut self.phin);
    }

    /// Run `steps` split-phase time steps in **one** pool dispatch — the
    /// multi-step pipelined protocol. Per epoch the same interior/boundary
    /// kernels as [`Self::step_overlapped_with`] run over the compiled
    /// [`ComputeSplit`], so the batch is bitwise identical to `steps`
    /// sequential (or overlapped) steps; across epochs the consumed-epoch
    /// ack protocol lets fast threads run up to D epochs (the runtime's
    /// pipeline depth, 2 by default — see [`set_depth`](Self::set_depth))
    /// ahead of their slowest receiver with no per-step dispatch and no
    /// barrier. The driver leaves the final field under `phi`, so no swap
    /// is needed here.
    pub fn run_pipelined_with(&mut self, engine: Engine, steps: usize) {
        let grid = self.grid;
        let (_, n) = grid.subdomain();
        let split = &self.split;
        self.runtime.run_pipelined(
            engine,
            steps,
            &mut self.phi,
            &mut self.phin,
            |_t, phi, phin| {
                jacobi_blocks(n, &split.interior, phi, phin);
            },
            |t, phi, phin| {
                jacobi_blocks(n, &split.boundary, phi, phin);
                Self::fixed_boundary_copy(grid, t, phi, phin);
            },
        );
        self.inter_thread_bytes += steps as u64 * self.runtime.payload_bytes();
    }

    /// Run pipelined steps until the Jacobi residual `max |phin − phi|`
    /// over every owned cell reaches `tol`, with **no global barrier**:
    /// each epoch's residual flows up a [`ReductionPlan`] tree combine and
    /// workers gate the next epoch on the root's verdict for this one.
    /// The batch stops at exactly the step a synchronous
    /// check-[`residual`](Self::residual)-every-step loop would stop at
    /// (bitwise — both fold in [`tree_fold`] order), or after `max_steps`
    /// if the tolerance is never reached. Returns the steps executed.
    pub fn run_pipelined_until_with(
        &mut self,
        engine: Engine,
        max_steps: usize,
        tol: f64,
    ) -> usize {
        let grid = self.grid;
        let (m, n) = grid.subdomain();
        let split = &self.split;
        let reduction = ReductionPlan::new(grid.threads(), ReduceOp::Max, tol)
            .with_deadline(self.runtime.wait_deadline());
        let executed = self.runtime.run_pipelined_until(
            engine,
            max_steps,
            &mut self.phi,
            &mut self.phin,
            |_t, phi, phin| {
                jacobi_blocks(n, &split.interior, phi, phin);
            },
            |t, phi, phin| {
                jacobi_blocks(n, &split.boundary, phi, phin);
                Self::fixed_boundary_copy(grid, t, phi, phin);
            },
            |_t, phi, phin| owned_residual(m, n, phi, phin),
            &reduction,
        );
        self.inter_thread_bytes += executed as u64 * self.runtime.payload_bytes();
        executed
    }

    /// The residual of the *last completed* step — per-thread
    /// `max |phi − phin|` over owned cells, folded in [`tree_fold`] order.
    /// This is the exact quantity
    /// [`run_pipelined_until_with`](Self::run_pipelined_until_with) stops
    /// on, so a synchronous loop checking it reproduces the same stopping
    /// step.
    pub fn residual(&self) -> f64 {
        let (m, n) = self.grid.subdomain();
        let per: Vec<f64> = (0..self.grid.threads())
            .map(|t| owned_residual(m, n, &self.phi[t], &self.phin[t]))
            .collect();
        tree_fold(ReduceOp::Max, &per)
    }

    /// Listing 8 for one thread: the 5-point Jacobi update of the interior
    /// plus the fixed global-boundary copy-through. Shared by both engines —
    /// it only touches thread `t`'s own `(phi, phin)` pair, so fusing it
    /// per-thread is order-independent.
    pub(crate) fn jacobi_update(grid: HeatGrid, t: usize, phi: &[f64], phin: &mut [f64]) {
        let (m, n) = grid.subdomain();
        for i in 1..m - 1 {
            for k in 1..n - 1 {
                phin[i * n + k] = 0.25
                    * (phi[(i - 1) * n + k]
                        + phi[(i + 1) * n + k]
                        + phi[i * n + k - 1]
                        + phi[i * n + k + 1]);
            }
        }
        Self::fixed_boundary_copy(grid, t, phi, phin);
    }

    /// Global-boundary rows/cols stay fixed (Dirichlet): copy them through.
    /// Runs after every cell update on both step protocols, reading the
    /// freshly exchanged halo, so its final-write order is unchanged.
    pub(crate) fn fixed_boundary_copy(grid: HeatGrid, t: usize, phi: &[f64], phin: &mut [f64]) {
        let (m, n) = grid.subdomain();
        let (ip, kp) = grid.coords(t);
        if ip == 0 {
            for k in 0..n {
                phin[n + k] = phi[n + k];
            }
        }
        if ip == grid.mprocs - 1 {
            for k in 0..n {
                phin[(m - 2) * n + k] = phi[(m - 2) * n + k];
            }
        }
        if kp == 0 {
            for i in 0..m {
                phin[i * n + 1] = phi[i * n + 1];
            }
        }
        if kp == grid.nprocs - 1 {
            for i in 0..m {
                phin[i * n + n - 2] = phi[i * n + n - 2];
            }
        }
    }

    /// Gather the global interior field (for comparison with the reference).
    pub fn to_global(&self) -> Vec<f64> {
        let grid = self.grid;
        let (m, n) = grid.subdomain();
        let mut out = vec![0.0f64; grid.m_glob * grid.n_glob];
        for t in 0..grid.threads() {
            let (ip, kp) = grid.coords(t);
            let (row0, col0) = (ip * (m - 2), kp * (n - 2));
            for i in 1..m - 1 {
                for k in 1..n - 1 {
                    out[(row0 + i - 1) * grid.n_glob + (col0 + k - 1)] =
                        self.phi[t][i * n + k];
                }
            }
        }
        out
    }
}

/// The 5-point Jacobi expression over a list of [`StridedBlock`] cell sets
/// (row stride `n`). Per-cell expression and operand order are identical to
/// [`Heat2dSolver::jacobi_update`]'s nested loops, and Jacobi writes each
/// cell once, so any partition of the owned region evaluates bitwise
/// identically.
pub(crate) fn jacobi_blocks(n: usize, blocks: &[StridedBlock], phi: &[f64], phin: &mut [f64]) {
    for b in blocks {
        for r in 0..b.rows {
            let base = b.offset + r * b.row_stride;
            for cc in 0..b.cols {
                let c = base + cc * b.col_stride;
                phin[c] = 0.25 * (phi[c - n] + phi[c + n] + phi[c - 1] + phi[c + 1]);
            }
        }
    }
}

/// The boundary cells of an `m × n` subdomain that [`Heat2dSolver::step_fused`]
/// did *not* cover with a fused ghost-row pass: the top/bottom owned rows
/// when unfused, plus the side columns over the middle rows. Mirrors
/// [`ComputeSplit::grid2d`]'s frame decomposition (each boundary cell
/// exactly once), minus the fused rows.
fn residual_boundary(m: usize, n: usize, fuse_up: bool, fuse_down: bool) -> Vec<StridedBlock> {
    let mut blocks = Vec::new();
    if !fuse_up {
        blocks.push(StridedBlock::row(n + 1, n - 2));
    }
    if m - 2 > 1 && !fuse_down {
        blocks.push(StridedBlock::row((m - 2) * n + 1, n - 2));
    }
    // Side columns over rows 2..=m−3 (empty when no middle rows exist).
    let mid_rows = m.saturating_sub(4);
    if mid_rows > 0 {
        blocks.push(StridedBlock::column(2 * n + 1, mid_rows, n));
        if n - 2 > 1 {
            blocks.push(StridedBlock::column(2 * n + (n - 2), mid_rows, n));
        }
    }
    blocks
}

/// `max |a − b|` over the owned cells (rows `1..m−1` × cols `1..n−1`) of an
/// `m × n` halo-extended subdomain — the per-thread Jacobi residual when
/// called on the old/new field pair. `|x|` is sign-symmetric, so the caller
/// may pass the buffers in either order and get the same bits.
fn owned_residual(m: usize, n: usize, a: &[f64], b: &[f64]) -> f64 {
    let mut r = 0.0f64;
    for i in 1..m - 1 {
        for k in 1..n - 1 {
            r = r.max((a[i * n + k] - b[i * n + k]).abs());
        }
    }
    r
}

/// Thread `t`'s halo-extended `m × n` field cut from the global domain:
/// interior cells plus whatever halo overlaps the global field (out-of-range
/// halo stays 0). Shared by the in-process solver and the per-rank
/// distributed drivers so every backend starts bitwise identical.
pub(crate) fn initial_field(grid: HeatGrid, global: &[f64], t: usize) -> Vec<f64> {
    let (m, n) = grid.subdomain();
    let (ip, kp) = grid.coords(t);
    let (row0, col0) = (ip * (m - 2), kp * (n - 2));
    let mut field = vec![0.0f64; m * n];
    for i in 0..m {
        for k in 0..n {
            let gi = row0 as isize + i as isize - 1;
            let gk = col0 as isize + k as isize - 1;
            if gi >= 0 && (gi as usize) < grid.m_glob && gk >= 0 && (gk as usize) < grid.n_glob {
                field[i * n + k] = global[gi as usize * grid.n_glob + gk as usize];
            }
        }
    }
    field
}

/// Sequential reference: one Jacobi step on the global field (fixed global
/// boundary).
pub fn seq_reference_step(m_glob: usize, n_glob: usize, phi: &[f64]) -> Vec<f64> {
    let mut out = phi.to_vec();
    for i in 1..m_glob - 1 {
        for k in 1..n_glob - 1 {
            out[i * n_glob + k] = 0.25
                * (phi[(i - 1) * n_glob + k]
                    + phi[(i + 1) * n_glob + k]
                    + phi[i * n_glob + k - 1]
                    + phi[i * n_glob + k + 1]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_field(m: usize, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..m * n).map(|_| rng.f64_in(0.0, 100.0)).collect()
    }

    #[test]
    fn parallel_matches_sequential_over_steps() {
        let (mg, ng) = (36, 48);
        let grid = HeatGrid::new(mg, ng, 3, 4);
        let f0 = random_field(mg, ng, 42);
        let mut solver = Heat2dSolver::new(grid, &f0);
        let mut reference = f0.clone();
        for step in 0..10 {
            solver.step();
            reference = seq_reference_step(mg, ng, &reference);
            let got = solver.to_global();
            for (idx, (a, b)) in got.iter().zip(&reference).enumerate() {
                assert!(
                    (a - b).abs() < 1e-12,
                    "step {step} idx {idx}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn single_thread_grid_works() {
        let grid = HeatGrid::new(16, 16, 1, 1);
        let f0 = random_field(16, 16, 7);
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.step();
        let want = seq_reference_step(16, 16, &f0);
        let got = solver.to_global();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
        // No neighbours → no inter-thread traffic.
        assert_eq!(solver.inter_thread_bytes, 0);
    }

    #[test]
    fn halo_traffic_counted() {
        let grid = HeatGrid::new(24, 24, 2, 2);
        let f0 = random_field(24, 24, 3);
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.step();
        // Each of 4 threads has 2 neighbours; message length = 12 doubles.
        // Total = 8 messages · 12 · 8 bytes.
        assert_eq!(solver.inter_thread_bytes, 8 * 12 * 8);
        assert_eq!(solver.runtime().plan().num_messages(), 8);
        assert_eq!(solver.runtime().plan().total_values(), 8 * 12);
    }

    #[test]
    fn compiled_plan_is_consistent() {
        for (mg, ng, mp, np) in
            [(36usize, 48usize, 3usize, 4usize), (16, 16, 1, 1), (12, 60, 1, 6), (60, 12, 6, 1)]
        {
            let grid = HeatGrid::new(mg, ng, mp, np);
            let (m, n) = grid.subdomain();
            let plan = halo_plan(&grid);
            plan.validate(&|_| m * n).unwrap();
            crate::comm::ExchangePlan::from(plan.clone()).validate(&|_| m * n).unwrap();
            // One message per directed neighbour pair.
            let expected: usize = (0..grid.threads()).map(|t| grid.neighbours(t).len()).sum();
            assert_eq!(plan.num_messages(), expected, "{mp}x{np}");
            // The interior/boundary split covers the owned region exactly.
            let split = compute_split(&grid);
            split.validate(&ComputeSplit::owned2d(m, n), m * n).unwrap();
        }
    }

    #[test]
    fn overlapped_step_bitwise_identical() {
        let grid = HeatGrid::new(36, 48, 3, 4);
        let f0 = random_field(36, 48, 21);
        let mut sync = Heat2dSolver::new(grid, &f0);
        let mut ovl_seq = Heat2dSolver::new(grid, &f0);
        let mut ovl_par = Heat2dSolver::new(grid, &f0);
        for step in 0..6 {
            sync.step_with(Engine::Sequential);
            ovl_seq.step_overlapped_with(Engine::Sequential);
            ovl_par.step_overlapped_with(Engine::Parallel);
            let want = sync.to_global();
            assert!(
                want.iter().zip(&ovl_seq.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "seq overlap diverges at step {step}"
            );
            assert!(
                want.iter().zip(&ovl_par.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "par overlap diverges at step {step}"
            );
            assert_eq!(sync.inter_thread_bytes, ovl_par.inter_thread_bytes, "step {step}");
        }
    }

    #[test]
    fn pipelined_batch_bitwise_identical() {
        let grid = HeatGrid::new(36, 48, 3, 4);
        let f0 = random_field(36, 48, 33);
        let mut sync = Heat2dSolver::new(grid, &f0);
        let mut pipe_seq = Heat2dSolver::new(grid, &f0);
        let mut pipe_par = Heat2dSolver::new(grid, &f0);
        // Batches of varying size, including a single-step batch.
        for (round, steps) in [(0usize, 3usize), (1, 1), (2, 4), (3, 2)] {
            for _ in 0..steps {
                sync.step_with(Engine::Sequential);
            }
            pipe_seq.run_pipelined_with(Engine::Sequential, steps);
            pipe_par.run_pipelined_with(Engine::Parallel, steps);
            let want = sync.to_global();
            assert!(
                want.iter().zip(&pipe_seq.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "seq pipeline diverges in round {round}"
            );
            assert!(
                want.iter().zip(&pipe_par.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "par pipeline diverges in round {round}"
            );
            assert_eq!(sync.inter_thread_bytes, pipe_par.inter_thread_bytes, "round {round}");
        }
        // The whole 4-step batch cost one dispatch, and the ack protocol
        // held the depth bound (default D = 2).
        assert!(pipe_par.runtime().max_sender_lead() <= pipe_par.depth() as u64);
    }

    #[test]
    fn fused_step_bitwise_identical() {
        // The fused unpack+boundary step must stay bitwise locked to the
        // synchronous oracle on a grid where middle ranks fuse both rows,
        // edge ranks fuse one, and corner-adjacent structure varies.
        let grid = HeatGrid::new(36, 48, 3, 4);
        let f0 = random_field(36, 48, 55);
        let mut sync = Heat2dSolver::new(grid, &f0);
        let mut fused = Heat2dSolver::new(grid, &f0);
        for step in 0..6 {
            sync.step_with(Engine::Sequential);
            fused.step_fused();
            let want = sync.to_global();
            assert!(
                want.iter().zip(&fused.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "fused step diverges at step {step}"
            );
            assert_eq!(sync.inter_thread_bytes, fused.inter_thread_bytes, "step {step}");
        }
        // Fused steps share the epoch bookkeeping, so they interleave with
        // the other protocols on the same solver.
        fused.step_overlapped_with(Engine::Parallel);
        sync.step_with(Engine::Sequential);
        assert!(sync
            .to_global()
            .iter()
            .zip(&fused.to_global())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn fused_step_short_subdomain_falls_back() {
        // m = (8−2)/3 + 2 = 4: the minimum fusable height (fused rows read
        // each other's phi rows, never ghosts) — and a 1-row-high variant
        // (m = 3) that must fall back to plain unpacking entirely.
        for (mg, mp) in [(8usize, 3usize), (5, 3)] {
            let grid = HeatGrid::new(mg, 24, mp, 2);
            let f0 = random_field(mg, 24, 91);
            let mut sync = Heat2dSolver::new(grid, &f0);
            let mut fused = Heat2dSolver::new(grid, &f0);
            for step in 0..4 {
                sync.step_with(Engine::Sequential);
                fused.step_fused();
                assert!(
                    sync.to_global()
                        .iter()
                        .zip(&fused.to_global())
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "mg={mg} mp={mp} diverges at step {step}"
                );
            }
        }
    }

    #[test]
    fn pipelined_depth_sweep_bitwise_identical() {
        // Depth-D pipelines through the solver API: every D matches the
        // synchronous oracle and respects its own lead bound.
        let grid = HeatGrid::new(36, 48, 3, 4);
        let f0 = random_field(36, 48, 77);
        let mut sync = Heat2dSolver::new(grid, &f0);
        for _ in 0..5 {
            sync.step_with(Engine::Sequential);
        }
        let want = sync.to_global();
        for depth in [1usize, 2, 3, 4] {
            let mut pipe = Heat2dSolver::new(grid, &f0);
            pipe.set_depth(depth);
            assert_eq!(pipe.depth(), depth);
            pipe.run_pipelined_with(Engine::Parallel, 5);
            assert!(
                want.iter().zip(&pipe.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                "depth {depth} diverges"
            );
            assert!(
                pipe.runtime().max_sender_lead() <= depth as u64,
                "depth {depth} lead {}",
                pipe.runtime().max_sender_lead()
            );
        }
    }

    #[test]
    fn tolerance_stop_matches_synchronous_check() {
        // The barrier-free tolerance stop must halt at *exactly* the step a
        // synchronous check-every-step loop halts at, on both engines, for
        // loose, medium, and tight tolerances.
        let grid = HeatGrid::new(24, 24, 2, 2);
        let f0 = random_field(24, 24, 13);
        let max_steps = 80usize;
        for tol in [50.0f64, 5.0, 0.05] {
            let mut sync = Heat2dSolver::new(grid, &f0);
            let mut want_steps = max_steps;
            for s in 1..=max_steps {
                sync.step_with(Engine::Sequential);
                if sync.residual() <= tol {
                    want_steps = s;
                    break;
                }
            }
            let want = sync.to_global();
            for engine in [Engine::Sequential, Engine::Parallel] {
                let mut pipe = Heat2dSolver::new(grid, &f0);
                pipe.runtime_mut()
                    .set_wait_deadline(Some(std::time::Duration::from_secs(5)));
                let executed = pipe.run_pipelined_until_with(engine, max_steps, tol);
                assert_eq!(executed, want_steps, "tol {tol} {engine:?}");
                assert!(
                    want.iter().zip(&pipe.to_global()).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "tol {tol} {engine:?}: fields diverge at the stopping step"
                );
                assert_eq!(
                    sync.inter_thread_bytes, pipe.inter_thread_bytes,
                    "tol {tol} {engine:?}"
                );
            }
        }
    }

    #[test]
    fn tolerance_stop_exhausts_unreachable_tolerance() {
        let grid = HeatGrid::new(16, 16, 2, 1);
        let f0 = random_field(16, 16, 29);
        let mut pipe = Heat2dSolver::new(grid, &f0);
        pipe.runtime_mut().set_wait_deadline(Some(std::time::Duration::from_secs(5)));
        // Negative tolerance can never be reached (residuals are >= 0):
        // the batch runs to max_steps and matches the plain pipelined run.
        let executed = pipe.run_pipelined_until_with(Engine::Parallel, 7, -1.0);
        assert_eq!(executed, 7);
        let mut plain = Heat2dSolver::new(grid, &f0);
        plain.run_pipelined_with(Engine::Parallel, 7);
        assert!(plain
            .to_global()
            .iter()
            .zip(&pipe.to_global())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn parallel_engine_matches_sequential_bitwise() {
        let grid = HeatGrid::new(36, 48, 3, 4);
        let f0 = random_field(36, 48, 11);
        let mut seq = Heat2dSolver::new(grid, &f0);
        let mut par = Heat2dSolver::new(grid, &f0);
        for step in 0..6 {
            seq.step_with(Engine::Sequential);
            par.step_with(Engine::Parallel);
            assert_eq!(seq.to_global(), par.to_global(), "step {step}");
            assert_eq!(seq.inter_thread_bytes, par.inter_thread_bytes, "step {step}");
        }
    }

    #[test]
    fn diffusion_smooths() {
        let grid = HeatGrid::new(32, 32, 2, 2);
        let mut f0 = vec![0.0f64; 32 * 32];
        f0[16 * 32 + 16] = 1000.0; // hot spot
        let mut solver = Heat2dSolver::new(grid, &f0);
        for _ in 0..20 {
            solver.step();
        }
        let out = solver.to_global();
        let max = out.iter().cloned().fold(0.0f64, f64::max);
        assert!(max < 1000.0 * 0.5, "peak should diffuse, max={max}");
        assert!(out.iter().all(|&v| v >= -1e-12));
    }
}
