//! The distributed backend: one private arena per rank, epochs on the wire.
//!
//! [`SocketTransport`] runs the exact protocols of the in-process engine
//! across `TcpStream`s. Each rank allocates the **full** depth-D staging
//! arena (`depth × total_values` doubles, slot = `epoch mod depth`; the
//! default depth 2 is the classic double buffer) privately and addresses it
//! with the same global plan coordinates, so pack/unpack code is identical
//! on both backends; the difference is purely how a packed range becomes
//! visible to its receiver:
//!
//! * `publish(e)` writes one [`KIND_DATA`](super::wire::KIND_DATA) frame
//!   per outgoing plan message (header carries `e` + the arena start slot).
//! * A per-peer reader thread parks frames in a mailbox; `wait_for_epoch`
//!   drains epoch-`e` frames into the local arena and completes once every
//!   expected frame from that sender arrived. Senders running ahead are
//!   harmless: their frames simply wait in the mailbox (the receiver's
//!   arena is private, so nothing is overwritten early).
//! * `ack(e)` sends empty `KIND_ACK` frames to this rank's senders;
//!   `wait_for_ack` waits on the max ack epoch received from a receiver.
//!
//! Reader threads never touch the arena — only the protocol thread does —
//! so the backend needs no atomics beyond the mailbox mutex. A dead peer
//! (connection reset / EOF) or an expired deadline converts every
//! subsequent wait into a structured [`StallError`] naming the peer's
//! socket identity.

use super::wire::{self, KIND_ACK, KIND_DATA, KIND_DELTA};
use super::Transport;
use crate::comm::{ExchangePlan, PlanDelta};
use crate::engine::{Phase, StallError, WaitTuning};
use std::io::Write;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::ops::Range;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One rank's row of a fully-connected mesh: `streams[p]` is the connection
/// to peer `p` (`None` at the rank's own slot and for non-peers).
pub type MeshStreams = Vec<Option<TcpStream>>;

/// One outgoing plan message: peer rank plus the arena range it carries.
#[derive(Debug, Clone, Copy)]
struct SendMsg {
    peer: usize,
    start: usize,
    len: usize,
}

/// Frames parked by the reader threads until the protocol thread drains
/// them: per-peer `(epoch, start, payload)` data frames, max ack epoch per
/// peer, and per-peer death notices.
#[derive(Debug)]
struct MailState {
    frames: Vec<Vec<(u64, u32, Vec<f64>)>>,
    /// Parked [`KIND_DELTA`] frames per peer: `(generation, true byte
    /// length, padded JSON body)`. Drained by [`SocketTransport::recv_delta`]
    /// at rebuild boundaries, never by the epoch path.
    deltas: Vec<Vec<(u64, u32, Vec<f64>)>>,
    acked: Vec<u64>,
    dead: Vec<Option<String>>,
    shutdown: bool,
}

#[derive(Debug)]
struct Mailbox {
    state: Mutex<MailState>,
    cv: Condvar,
}

/// A rank's view of a compiled plan: arena size, outgoing messages, data
/// frames expected per sender per epoch, and the distinct sender set.
/// Shared between construction and [`SocketTransport::install_plan`] so a
/// generation swap recomputes exactly what the constructor computed.
fn plan_shape(rank: usize, plan: &ExchangePlan) -> (usize, Vec<SendMsg>, Vec<usize>, Vec<usize>) {
    let procs = plan.threads();
    let mut sends = Vec::new();
    let mut expected = vec![0usize; procs];
    match plan {
        ExchangePlan::Gather(p) => {
            for m in p.send_msgs(rank) {
                let (peer, start) = (m.peer as usize, m.range().start);
                sends.push(SendMsg { peer, start, len: m.len() });
            }
            for m in p.recv_msgs(rank) {
                expected[m.peer as usize] += 1;
            }
        }
        ExchangePlan::Strided(p) => {
            for m in p.send_msgs(rank) {
                let (peer, start) = (m.peer as usize, m.range().start);
                sends.push(SendMsg { peer, start, len: m.len() });
            }
            for m in p.recv_msgs(rank) {
                expected[m.peer as usize] += 1;
            }
        }
    }
    let senders: Vec<usize> = (0..procs).filter(|&p| expected[p] > 0).collect();
    (plan.total_values(), sends, expected, senders)
}

/// A [`Transport`] endpoint over a mesh of byte streams.
pub struct SocketTransport {
    rank: usize,
    total: usize,
    /// Buffered arena slots (`arena.len() = depth × total`); the pipelined
    /// gate keeps senders at most `depth` epochs ahead, so slot
    /// `epoch mod depth` is always quiescent when reused.
    depth: usize,
    arena: Vec<f64>,
    /// Write side per peer; reader threads own `try_clone`d read sides.
    streams: Vec<Option<TcpStream>>,
    peer_ids: Vec<String>,
    sends: Vec<SendMsg>,
    /// Distinct peers this rank receives data from (= ack targets).
    senders: Vec<usize>,
    /// Data frames expected per sender per epoch.
    expected: Vec<usize>,
    /// Highest epoch fully drained per peer (wait idempotence).
    drained: Vec<u64>,
    mailbox: Arc<Mailbox>,
    readers: Vec<JoinHandle<()>>,
    deadline: Option<Duration>,
    /// Wait-ladder tuning; only `socket_slice` (the condvar-wait slice of
    /// the mailbox waits) applies to this blocking backend.
    tuning: WaitTuning,
    sent_bytes: u64,
    sent_frames: u64,
}

impl SocketTransport {
    /// Wire rank `rank`'s endpoint onto `streams` (its row of a mesh, e.g.
    /// from [`loopback_mesh`]) for the given compiled plan, with the
    /// default depth-2 staging arena. Spawns one reader thread per
    /// connected peer. `deadline` bounds every wait.
    pub fn new(
        rank: usize,
        plan: &ExchangePlan,
        streams: MeshStreams,
        deadline: Option<Duration>,
    ) -> std::io::Result<SocketTransport> {
        SocketTransport::with_depth(rank, plan, streams, deadline, 2)
    }

    /// [`new`](SocketTransport::new) with an explicit pipeline depth: the
    /// private arena holds `depth` buffered slots, so the pipelined driver
    /// may run senders up to `depth` epochs ahead of their receivers.
    pub fn with_depth(
        rank: usize,
        plan: &ExchangePlan,
        streams: MeshStreams,
        deadline: Option<Duration>,
        depth: usize,
    ) -> std::io::Result<SocketTransport> {
        assert!(depth >= 1, "pipeline depth must be at least 1");
        let procs = plan.threads();
        assert_eq!(streams.len(), procs, "mesh row arity");
        let (total, sends, expected, senders) = plan_shape(rank, plan);
        let peer_ids: Vec<String> = (0..procs)
            .map(|p| match &streams[p] {
                Some(s) => match s.peer_addr() {
                    Ok(a) => format!("socket:rank-{p}@{a}"),
                    Err(_) => format!("socket:rank-{p}"),
                },
                None => format!("socket:rank-{p}"),
            })
            .collect();
        let mailbox = Arc::new(Mailbox {
            state: Mutex::new(MailState {
                frames: vec![Vec::new(); procs],
                deltas: vec![Vec::new(); procs],
                acked: vec![0; procs],
                dead: vec![None; procs],
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let mut readers = Vec::new();
        for (peer, slot) in streams.iter().enumerate() {
            let Some(stream) = slot else { continue };
            stream.set_nodelay(true)?;
            let mut read_side = stream.try_clone()?;
            let mb = Arc::clone(&mailbox);
            let identity = peer_ids[peer].clone();
            readers.push(std::thread::spawn(move || loop {
                match wire::read_frame(&mut read_side) {
                    Ok(f) => {
                        let mut st = mb.state.lock().unwrap();
                        match f.kind {
                            KIND_DATA => st.frames[peer].push((f.epoch, f.start, f.payload)),
                            KIND_ACK => st.acked[peer] = st.acked[peer].max(f.epoch),
                            KIND_DELTA => st.deltas[peer].push((f.epoch, f.start, f.payload)),
                            _ => {} // late HELLO / unknown: ignore
                        }
                        drop(st);
                        mb.cv.notify_all();
                    }
                    Err(e) => {
                        let mut st = mb.state.lock().unwrap();
                        if !st.shutdown {
                            st.dead[peer] = Some(format!("{identity}: {e}"));
                        }
                        drop(st);
                        mb.cv.notify_all();
                        return;
                    }
                }
            }));
        }
        Ok(SocketTransport {
            rank,
            total,
            depth,
            arena: vec![0.0; depth * total],
            streams,
            peer_ids,
            sends,
            senders,
            expected,
            drained: vec![0; procs],
            mailbox,
            readers,
            deadline,
            tuning: WaitTuning::default(),
            sent_bytes: 0,
            sent_frames: 0,
        })
    }

    /// The configured pipeline depth (buffered arena slots).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Ship a [`PlanDelta`] to `peer` as one [`KIND_DELTA`] frame targeting
    /// plan generation `generation` — the incremental alternative to
    /// re-sending a whole compiled plan at a rebuild boundary. The body is
    /// the delta's canonical JSON; the receiver recovers it with
    /// [`recv_delta`](SocketTransport::recv_delta) and applies it locally.
    pub fn send_delta(
        &mut self,
        peer: usize,
        generation: u64,
        delta: &PlanDelta,
    ) -> Result<(), String> {
        let body = delta.to_json().compact();
        let (true_len, payload) = wire::delta_payload(body.as_bytes());
        let rank = self.rank as u32;
        let stream = self.streams[peer]
            .as_mut()
            .ok_or_else(|| format!("delta to a non-peer rank {peer}"))?;
        wire::write_frame(stream, KIND_DELTA, rank, generation, true_len, &payload)
            .map_err(|e| format!("delta to {}: {e}", self.peer_ids[peer]))
    }

    /// Wait for the [`KIND_DELTA`] frame targeting `generation` from `peer`
    /// and decode it. Frames for other generations stay parked (a fast
    /// coordinator may ship several rebuilds ahead); the configured deadline
    /// bounds the wait.
    pub fn recv_delta(&mut self, peer: usize, generation: u64) -> Result<PlanDelta, String> {
        let start = Instant::now();
        let mb = Arc::clone(&self.mailbox);
        let mut st = mb.state.lock().unwrap();
        loop {
            let buf = &mut st.deltas[peer];
            if let Some(i) = buf.iter().position(|(g, _, _)| *g == generation) {
                let (_, true_len, payload) = buf.swap_remove(i);
                drop(st);
                let bytes = wire::delta_bytes(true_len, &payload)?;
                let text = String::from_utf8(bytes)
                    .map_err(|_| "delta body is not UTF-8".to_string())?;
                let v = crate::util::json::parse(&text).map_err(|e| format!("delta JSON: {e}"))?;
                return PlanDelta::from_json(&v);
            }
            if let Some(note) = &st.dead[peer] {
                return Err(format!("peer died before shipping generation {generation}: {note}"));
            }
            let slice = match self.deadline {
                Some(d) => {
                    let waited = start.elapsed();
                    if waited >= d {
                        return Err(format!(
                            "delta stall: rank {} waited {waited:?} for generation {generation} \
                             from {}",
                            self.rank, self.peer_ids[peer]
                        ));
                    }
                    (d - waited).min(self.tuning.socket_slice)
                }
                None => self.tuning.socket_slice,
            };
            st = mb.cv.wait_timeout(st, slice).unwrap().0;
        }
    }

    /// Swap in a new plan generation without tearing the transport down:
    /// recompute this rank's sends / expected-frame counts / sender set and
    /// resize the arena, keeping the sockets, reader threads, mailbox, and
    /// drained/traffic counters. Safe at a rebuild boundary because every
    /// epoch of the old generation has been drained by then and frames from
    /// senders already running in the new generation are still parked in
    /// the mailbox (they are drained only after this returns, against the
    /// new shape).
    pub fn install_plan(&mut self, rank_plan: &ExchangePlan) {
        assert_eq!(rank_plan.threads(), self.streams.len(), "plan arity changed mid-run");
        let (total, sends, expected, senders) = plan_shape(self.rank, rank_plan);
        self.total = total;
        self.sends = sends;
        self.expected = expected;
        self.senders = senders;
        self.arena.clear();
        self.arena.resize(self.depth * total, 0.0);
    }

    /// Set the wait-ladder tuning; for this blocking backend only
    /// `socket_slice` (the mailbox condvar-wait slice) is consulted.
    pub fn set_wait_tuning(&mut self, tuning: WaitTuning) {
        self.tuning = tuning;
    }

    #[inline]
    fn half(&self, epoch: u64) -> usize {
        (epoch % self.depth as u64) as usize * self.total
    }

    fn stall(&self, peer: Option<usize>, epoch: u64, phase: Phase, waited: Duration) -> StallError {
        StallError {
            waiter: self.rank,
            peer,
            epoch,
            phase,
            waited,
            transport: peer.map(|p| self.peer_ids[p].clone()),
        }
    }

    /// Send `frame_kind` with `epoch` to `peer`; a broken pipe converts to
    /// a [`StallError`] naming the peer (the socket analogue of a peer that
    /// died before its flag arrived).
    fn send_control(
        &mut self,
        peer: usize,
        kind: u8,
        epoch: u64,
        phase: Phase,
    ) -> Result<(), StallError> {
        let rank = self.rank as u32;
        let stream = self.streams[peer].as_mut().expect("control frame to a non-peer");
        wire::write_frame(stream, kind, rank, epoch, 0, &[])
            .map_err(|_| self.mk_stall_for(peer, epoch, phase))
    }

    fn mk_stall_for(&self, peer: usize, epoch: u64, phase: Phase) -> StallError {
        StallError {
            waiter: self.rank,
            peer: Some(peer),
            epoch,
            phase,
            waited: Duration::ZERO,
            transport: Some(self.peer_ids[peer].clone()),
        }
    }
}

impl Transport for SocketTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn kind(&self) -> &'static str {
        "socket"
    }

    fn peer_identity(&self, peer: usize) -> String {
        self.peer_ids[peer].clone()
    }

    fn publish(&mut self, epoch: u64) -> Result<(), StallError> {
        let h = self.half(epoch);
        let rank = self.rank as u32;
        // Index loop: iterating `&self.sends` would hold a borrow across the
        // `self.streams` writes below.
        #[allow(clippy::needless_range_loop)]
        for i in 0..self.sends.len() {
            let m = self.sends[i];
            // Frame payload serializes straight from the arena slot — the
            // kernel tier's contiguous fast path applied to the wire (no
            // per-frame staging Vec on the publish hot path).
            let (arena, streams) = (&self.arena, &mut self.streams);
            let payload = &arena[h + m.start..h + m.start + m.len];
            let stream = streams[m.peer].as_mut().expect("send message to a non-peer");
            let sent = wire::write_frame(stream, KIND_DATA, rank, epoch, m.start as u32, payload);
            if sent.is_err() {
                return Err(self.mk_stall_for(m.peer, epoch, Phase::Pack));
            }
            self.sent_bytes += (m.len * 8) as u64;
            self.sent_frames += 1;
        }
        Ok(())
    }

    fn wait_for_epoch(&mut self, peer: usize, epoch: u64) -> Result<(), StallError> {
        if self.drained[peer] >= epoch {
            return Ok(());
        }
        let need = self.expected[peer];
        let h = self.half(epoch);
        let start = Instant::now();
        let mut got = 0usize;
        let mb = Arc::clone(&self.mailbox);
        let mut st = mb.state.lock().unwrap();
        loop {
            // Drain this epoch's frames into the local arena.
            let buf = &mut st.frames[peer];
            let mut i = 0;
            while i < buf.len() {
                if buf[i].0 == epoch {
                    let (_, fstart, payload) = buf.swap_remove(i);
                    let at = h + fstart as usize;
                    self.arena[at..at + payload.len()].copy_from_slice(&payload);
                    got += 1;
                } else {
                    i += 1;
                }
            }
            if got >= need {
                self.drained[peer] = self.drained[peer].max(epoch);
                return Ok(());
            }
            if st.dead[peer].is_some() {
                return Err(self.stall(Some(peer), epoch, Phase::Transfer, start.elapsed()));
            }
            let slice = match self.deadline {
                Some(d) => {
                    let waited = start.elapsed();
                    if waited >= d {
                        return Err(self.stall(Some(peer), epoch, Phase::Transfer, waited));
                    }
                    (d - waited).min(self.tuning.socket_slice)
                }
                None => self.tuning.socket_slice,
            };
            st = mb.cv.wait_timeout(st, slice).unwrap().0;
        }
    }

    fn ack(&mut self, epoch: u64) -> Result<(), StallError> {
        // Index loop: `send_control` needs `&mut self` while `self.senders`
        // would otherwise stay borrowed.
        #[allow(clippy::needless_range_loop)]
        for i in 0..self.senders.len() {
            let peer = self.senders[i];
            self.send_control(peer, KIND_ACK, epoch, Phase::Unpack)?;
        }
        Ok(())
    }

    fn wait_for_ack(&mut self, peer: usize, epoch: u64) -> Result<(), StallError> {
        let start = Instant::now();
        let mb = Arc::clone(&self.mailbox);
        let mut st = mb.state.lock().unwrap();
        loop {
            if st.acked[peer] >= epoch {
                return Ok(());
            }
            if st.dead[peer].is_some() {
                return Err(self.stall(Some(peer), epoch, Phase::AckGate, start.elapsed()));
            }
            let slice = match self.deadline {
                Some(d) => {
                    let waited = start.elapsed();
                    if waited >= d {
                        return Err(self.stall(Some(peer), epoch, Phase::AckGate, waited));
                    }
                    (d - waited).min(self.tuning.socket_slice)
                }
                None => self.tuning.socket_slice,
            };
            st = mb.cv.wait_timeout(st, slice).unwrap().0;
        }
    }

    fn send_slot(&mut self, epoch: u64, range: Range<usize>) -> &mut [f64] {
        let h = self.half(epoch);
        &mut self.arena[h + range.start..h + range.end]
    }

    fn recv_slot(&mut self, epoch: u64, range: Range<usize>) -> &[f64] {
        let h = self.half(epoch);
        &self.arena[h + range.start..h + range.end]
    }

    fn sent_payload_bytes(&self) -> u64 {
        self.sent_bytes
    }

    fn sent_transfers(&self) -> u64 {
        self.sent_frames
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        self.mailbox.state.lock().unwrap().shutdown = true;
        self.mailbox.cv.notify_all();
        // Shutting down the write handles also unblocks the reader clones
        // (they share the underlying socket).
        for s in self.streams.iter().flatten() {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Build a fully-connected loopback TCP mesh for `procs` in-process ranks:
/// `mesh[i][j]` is rank `i`'s stream to rank `j`. Used by the in-process
/// socket world (tests, `repro validate --transport socket`); the
/// multi-process path builds its mesh across processes in
/// [`super::launch`].
pub fn loopback_mesh(procs: usize) -> std::io::Result<Vec<MeshStreams>> {
    let mut mesh: Vec<MeshStreams> =
        (0..procs).map(|_| (0..procs).map(|_| None).collect()).collect();
    for i in 0..procs {
        for j in i + 1..procs {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?;
            let a = TcpStream::connect(addr)?;
            let (b, _) = listener.accept()?;
            a.set_nodelay(true)?;
            b.set_nodelay(true)?;
            mesh[i][j] = Some(a);
            mesh[j][i] = Some(b);
        }
    }
    Ok(mesh)
}

/// Measured loopback-socket characteristics for the transport-aware model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SocketProbe {
    /// One-way small-message latency in seconds (median RTT / 2) — the
    /// socket analogue of the calibration's τ.
    pub latency: f64,
    /// Streaming bandwidth in bytes/s over 64 KiB writes — the analogue of
    /// the inter-node bandwidth parameter.
    pub bandwidth: f64,
}

/// Ping-pong + streaming probe over a loopback TCP pair, mirroring the τ /
/// STREAM microbenchmarks for the socket transport. `quick` trades
/// precision for CI speed (200 pings / 4 MiB vs 2000 pings / 32 MiB).
pub fn socket_probe(quick: bool) -> std::io::Result<SocketProbe> {
    let (pings, volume) = if quick { (200usize, 4usize << 20) } else { (2000, 32 << 20) };
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let server = std::thread::spawn(move || -> std::io::Result<()> {
        let (mut s, _) = listener.accept()?;
        s.set_nodelay(true)?;
        let mut b = [0u8; 1];
        for _ in 0..pings {
            std::io::Read::read_exact(&mut s, &mut b)?;
            s.write_all(&b)?;
        }
        let mut buf = vec![0u8; 64 << 10];
        let mut left = volume;
        while left > 0 {
            let n = std::io::Read::read(&mut s, &mut buf)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "probe stream closed early",
                ));
            }
            left = left.saturating_sub(n);
        }
        s.write_all(&[1])?;
        Ok(())
    });
    let mut c = TcpStream::connect(addr)?;
    c.set_nodelay(true)?;
    let mut b = [7u8; 1];
    let mut rtts = Vec::with_capacity(pings);
    for _ in 0..pings {
        let t0 = Instant::now();
        c.write_all(&b)?;
        std::io::Read::read_exact(&mut c, &mut b)?;
        rtts.push(t0.elapsed().as_secs_f64());
    }
    rtts.sort_by(f64::total_cmp);
    let latency = rtts[pings / 2] / 2.0;
    let chunk = vec![0u8; 64 << 10];
    let t0 = Instant::now();
    let mut left = volume;
    while left > 0 {
        let n = left.min(chunk.len());
        c.write_all(&chunk[..n])?;
        left -= n;
    }
    std::io::Read::read_exact(&mut c, &mut b)?;
    let bandwidth = volume as f64 / t0.elapsed().as_secs_f64();
    server.join().map_err(|_| std::io::Error::other("probe echo thread panicked"))??;
    Ok(SocketProbe { latency, bandwidth })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StridedBlock, StridedPlan};

    fn two_rank_plan() -> ExchangePlan {
        // Ranks 0 and 1 swap 3-value rows.
        StridedPlan::from_msgs(
            2,
            &[
                (0, 1, StridedBlock::row(0, 3), StridedBlock::row(3, 3)),
                (1, 0, StridedBlock::row(0, 3), StridedBlock::row(3, 3)),
            ],
        )
        .into()
    }

    #[test]
    fn socket_pair_exchanges_epochs_and_acks() {
        let plan = two_rank_plan();
        let mesh = loopback_mesh(2).unwrap();
        let deadline = Some(Duration::from_secs(10));
        let results: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, row)| {
                    let plan = &plan;
                    s.spawn(move || {
                        let mut t = SocketTransport::new(rank, plan, row, deadline).unwrap();
                        let mut seen = Vec::new();
                        for epoch in 1..=4u64 {
                            let base = (rank * 100) as f64 + epoch as f64;
                            let plan_s = plan.as_strided().unwrap();
                            for m in plan_s.send_msgs(rank) {
                                let slot = t.send_slot(epoch, m.range());
                                for (k, v) in slot.iter_mut().enumerate() {
                                    *v = base + k as f64 * 0.25;
                                }
                            }
                            t.publish(epoch).unwrap();
                            let peer = 1 - rank;
                            t.wait_for_epoch(peer, epoch).unwrap();
                            // Idempotent per (peer, epoch).
                            t.wait_for_epoch(peer, epoch).unwrap();
                            for m in plan_s.recv_msgs(rank) {
                                seen.extend_from_slice(t.recv_slot(epoch, m.range()));
                            }
                            t.ack(epoch).unwrap();
                            t.wait_for_ack(peer, epoch).unwrap();
                        }
                        assert_eq!(t.sent_transfers(), 4);
                        assert_eq!(t.sent_payload_bytes(), 4 * 3 * 8);
                        seen
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Rank 0 saw rank 1's packs and vice versa, all four epochs in order.
        for (rank, seen) in results.iter().enumerate() {
            let peer = (1 - rank) as f64;
            let want: Vec<f64> = (1..=4u64)
                .flat_map(|e| (0..3).map(move |k| peer * 100.0 + e as f64 + k as f64 * 0.25))
                .collect();
            assert_eq!(seen, &want, "rank {rank}");
        }
    }

    #[test]
    fn socket_pair_depth_3_rotates_slots() {
        // Same exchange as the depth-2 test but over a 3-slot arena and
        // more epochs than slots, so every slot gets reused at least once:
        // the `epoch mod depth` addressing must agree on both ends.
        let plan = two_rank_plan();
        let mesh = loopback_mesh(2).unwrap();
        let deadline = Some(Duration::from_secs(10));
        let results: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, row)| {
                    let plan = &plan;
                    s.spawn(move || {
                        let mut t =
                            SocketTransport::with_depth(rank, plan, row, deadline, 3).unwrap();
                        assert_eq!(t.depth(), 3);
                        let mut seen = Vec::new();
                        for epoch in 1..=7u64 {
                            let base = (rank * 100) as f64 + epoch as f64;
                            let plan_s = plan.as_strided().unwrap();
                            for m in plan_s.send_msgs(rank) {
                                let slot = t.send_slot(epoch, m.range());
                                for (k, v) in slot.iter_mut().enumerate() {
                                    *v = base + k as f64 * 0.25;
                                }
                            }
                            t.publish(epoch).unwrap();
                            let peer = 1 - rank;
                            t.wait_for_epoch(peer, epoch).unwrap();
                            for m in plan_s.recv_msgs(rank) {
                                seen.extend_from_slice(t.recv_slot(epoch, m.range()));
                            }
                            t.ack(epoch).unwrap();
                            t.wait_for_ack(peer, epoch).unwrap();
                        }
                        seen
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (rank, seen) in results.iter().enumerate() {
            let peer = (1 - rank) as f64;
            let want: Vec<f64> = (1..=7u64)
                .flat_map(|e| (0..3).map(move |k| peer * 100.0 + e as f64 + k as f64 * 0.25))
                .collect();
            assert_eq!(seen, &want, "rank {rank}");
        }
    }

    #[test]
    fn dead_peer_converts_to_stall_error() {
        let plan = two_rank_plan();
        let mut mesh = loopback_mesh(2).unwrap();
        let row1 = std::mem::take(&mut mesh[1]);
        let row0 = std::mem::take(&mut mesh[0]);
        drop(row1); // rank 1 "dies" before publishing anything
        let mut t = SocketTransport::new(0, &plan, row0, Some(Duration::from_secs(5))).unwrap();
        let err = t.wait_for_epoch(1, 1).unwrap_err();
        assert_eq!(err.waiter, 0);
        assert_eq!(err.peer, Some(1));
        assert!(err.transport.as_deref().unwrap_or("").starts_with("socket:rank-1"), "{err}");
    }

    #[test]
    fn slow_peer_hits_deadline_not_hang() {
        let plan = two_rank_plan();
        let mesh = loopback_mesh(2).unwrap();
        let mut rows = mesh.into_iter();
        let row0 = rows.next().unwrap();
        let _row1 = rows.next().unwrap(); // held open, never publishes
        let mut t = SocketTransport::new(0, &plan, row0, Some(Duration::from_millis(80))).unwrap();
        let start = Instant::now();
        let err = t.wait_for_epoch(1, 1).unwrap_err();
        assert!(start.elapsed() < Duration::from_secs(5), "deadline did not bound the wait");
        assert_eq!(err.phase, Phase::Transfer);
        assert!(err.waited >= Duration::from_millis(80));
    }

    #[test]
    fn delta_frame_ships_applies_and_reshapes_the_transport() {
        use crate::comm::{chain_fingerprint, CommPlan};
        use crate::pgas::Layout;
        // Blocks of 2 over 8 cells, 2 ranks: rank 0 owns {0,1,4,5}, rank 1
        // owns {2,3,6,7}. Generation 0: each rank needs one remote value;
        // generation 1 widens rank 0's needs to two values from rank 1.
        let layout = Layout::new(8, 2, 2);
        let gen0: ExchangePlan =
            CommPlan::from_recv_needs(&layout, &[vec![(1, 2)], vec![(0, 0)]]).into();
        let gen1: ExchangePlan =
            CommPlan::from_recv_needs(&layout, &[vec![(1, 2), (1, 3)], vec![(0, 0)]]).into();
        let delta = PlanDelta::diff(&gen0, &gen1).unwrap();
        let mesh = loopback_mesh(2).unwrap();
        let deadline = Some(Duration::from_secs(10));
        let fps: Vec<(u64, u64)> = std::thread::scope(|s| {
            let (gen0, gen1, delta) = (&gen0, &gen1, &delta);
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, row)| {
                    s.spawn(move || {
                        let mut t = SocketTransport::new(rank, gen0, row, deadline).unwrap();
                        let peer = 1 - rank;
                        // One epoch under generation 0.
                        let exchange = |t: &mut SocketTransport, plan: &ExchangePlan, e: u64| {
                            let p = plan.as_gather().unwrap();
                            for m in p.send_msgs(rank) {
                                for (k, v) in t.send_slot(e, m.range()).iter_mut().enumerate() {
                                    *v = (rank * 10) as f64 + e as f64 + k as f64;
                                }
                            }
                            t.publish(e).unwrap();
                            t.wait_for_epoch(peer, e).unwrap();
                            let mut seen = Vec::new();
                            for m in p.recv_msgs(rank) {
                                seen.extend_from_slice(t.recv_slot(e, m.range()));
                            }
                            seen
                        };
                        let seen0 = exchange(&mut t, gen0, 1);
                        assert_eq!(seen0.len(), 1, "rank {rank} gen0");
                        // Rebuild boundary: rank 0 ships the delta, rank 1
                        // receives and applies it; both verify the chain.
                        let applied = if rank == 0 {
                            t.send_delta(peer, 1, delta).unwrap();
                            gen0.apply_delta(delta).unwrap()
                        } else {
                            let d = t.recv_delta(peer, 1).unwrap();
                            assert_eq!(d.base_fingerprint(), gen0.fingerprint());
                            gen0.apply_delta(&d).unwrap()
                        };
                        t.install_plan(&applied);
                        let seen1 = exchange(&mut t, &applied, 2);
                        assert_eq!(seen1.len(), if rank == 0 { 2 } else { 1 }, "rank {rank} gen1");
                        (applied.fingerprint(), chain_fingerprint(gen0.fingerprint(), delta))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Both ranks converged on the from-scratch generation-1 plan and on
        // the same delta-chain fingerprint.
        for (fp, chain) in &fps {
            assert_eq!(*fp, gen1.fingerprint());
            assert_eq!(*chain, fps[0].1);
        }
        assert_eq!(fps[0], fps[1]);
    }

    #[test]
    fn probe_reports_positive_parameters() {
        let p = socket_probe(true).unwrap();
        assert!(p.latency > 0.0 && p.latency < 1.0, "latency {}", p.latency);
        assert!(p.bandwidth > 1e6, "bandwidth {}", p.bandwidth);
    }
}
