//! The in-process backend: epoch flags + shared staging arena.
//!
//! [`PoolEndpoint`] re-expresses the engine's pre-trait hot path — padded
//! release/acquire `EpochFlags` counters and disjoint `ArenaView` slices —
//! as a [`Transport`]. It is a pure view bundle: constructing one allocates
//! nothing and every method inlines to the same loads/stores the engine
//! issued before the refactor, keeping the protocols bitwise unchanged.

use super::Transport;
use crate::engine::{ArenaView, EpochFlags, StallError, WorkerCtx};
use std::ops::Range;

/// One pool worker's endpoint onto the shared-memory transport: its rank's
/// slot in the published/consumed [`EpochFlags`] plus the depth-D staging
/// arena (`depth × total` doubles, indexed by `epoch mod depth`).
///
/// Wait methods delegate to the pool's deadline/poison-aware primitives
/// ([`WorkerCtx::wait_for_epoch`] / [`WorkerCtx::wait_for_ack`]), which
/// raise [`StallError`] through the dispatch's poison path on expiry — so
/// from this endpoint they always return `Ok` and the engine's existing
/// `catch_unwind` recovery keeps working unmodified.
pub struct PoolEndpoint<'a> {
    rank: usize,
    total: usize,
    depth: usize,
    flags: &'a EpochFlags,
    acks: &'a EpochFlags,
    arena: &'a ArenaView<'a>,
    ctx: &'a WorkerCtx<'a>,
}

impl<'a> PoolEndpoint<'a> {
    /// Bundle worker `rank`'s views over a dispatch's shared state. `total`
    /// is the plan's `total_values()` (one arena slot); `depth` the number
    /// of buffered slots the arena holds (`arena.len() = depth × total`).
    ///
    /// # Safety
    /// `send_slot`/`recv_slot` hand out overlapping-lifetime slices of the
    /// shared arena. The caller must guarantee the compiled-plan contract
    /// the engine already relies on: slot ranges passed to `send_slot` are
    /// pairwise disjoint across workers within an epoch (plan messages tile
    /// the arena), and `recv_slot` ranges are only read after
    /// `wait_for_epoch` on the range's sender for that epoch.
    pub unsafe fn new(
        rank: usize,
        total: usize,
        depth: usize,
        flags: &'a EpochFlags,
        acks: &'a EpochFlags,
        arena: &'a ArenaView<'a>,
        ctx: &'a WorkerCtx<'a>,
    ) -> PoolEndpoint<'a> {
        debug_assert!(depth >= 1);
        PoolEndpoint { rank, total, depth, flags, acks, arena, ctx }
    }

    #[inline]
    fn half(&self, epoch: u64) -> usize {
        (epoch % self.depth as u64) as usize * self.total
    }
}

impl Transport for PoolEndpoint<'_> {
    #[inline]
    fn rank(&self) -> usize {
        self.rank
    }

    #[inline]
    fn kind(&self) -> &'static str {
        "inproc"
    }

    fn peer_identity(&self, peer: usize) -> String {
        format!("inproc:worker-{peer}")
    }

    #[inline]
    fn publish(&mut self, epoch: u64) -> Result<(), StallError> {
        self.flags.publish(self.rank, epoch);
        Ok(())
    }

    #[inline]
    fn wait_for_epoch(&mut self, peer: usize, epoch: u64) -> Result<(), StallError> {
        // Panics with a StallError through the pool's poison path on
        // deadline expiry — identical to the pre-trait engine behavior.
        self.ctx.wait_for_epoch(self.flags.flag(peer), epoch, peer);
        Ok(())
    }

    #[inline]
    fn ack(&mut self, epoch: u64) -> Result<(), StallError> {
        self.acks.publish(self.rank, epoch);
        Ok(())
    }

    #[inline]
    fn wait_for_ack(&mut self, peer: usize, epoch: u64) -> Result<(), StallError> {
        self.ctx.wait_for_ack(self.acks.flag(peer), epoch, peer);
        Ok(())
    }

    #[inline]
    fn send_slot(&mut self, epoch: u64, range: Range<usize>) -> &mut [f64] {
        let h = self.half(epoch);
        // SAFETY: disjointness and ordering are the constructor's contract.
        unsafe { self.arena.slice_mut(h + range.start..h + range.end) }
    }

    #[inline]
    fn recv_slot(&mut self, epoch: u64, range: Range<usize>) -> &[f64] {
        let h = self.half(epoch);
        // SAFETY: reads follow a wait_for_epoch on the range's sender.
        unsafe { self.arena.slice(h + range.start..h + range.end) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::WorkerPool;

    #[test]
    fn endpoint_moves_values_between_workers() {
        // Two workers exchange one double through the endpoint: worker 0
        // packs into slot 0, worker 1 into slot 1; each waits for the
        // peer's epoch and reads the other slot.
        let mut pool = WorkerPool::new();
        let flags = EpochFlags::new(2);
        let acks = EpochFlags::new(2);
        let total = 2usize;
        let mut staging = vec![0.0f64; 2 * total];
        let arena = ArenaView::new(&mut staging);
        let mut got = vec![0.0f64; 2];
        let gw = crate::engine::PerWorker::new(&mut got);
        pool.run(2, &|ctx| {
            let t = ctx.id;
            // SAFETY: slot ranges are disjoint per worker; reads follow the
            // epoch wait.
            let mut ep =
                unsafe { PoolEndpoint::new(t, total, 2, &flags, &acks, &arena, &ctx) };
            for epoch in 1..=3u64 {
                ep.send_slot(epoch, t..t + 1)[0] = (10 * t) as f64 + epoch as f64;
                super::super::must(ep.publish(epoch));
                let peer = 1 - t;
                super::super::must(ep.wait_for_epoch(peer, epoch));
                let v = ep.recv_slot(epoch, peer..peer + 1)[0];
                super::super::must(ep.ack(epoch));
                super::super::must(ep.wait_for_ack(peer, epoch));
                // SAFETY: each worker claims only its own slot.
                *unsafe { gw.take(t) } = v;
            }
            assert_eq!(ep.kind(), "inproc");
            assert_eq!(ep.rank(), t);
        });
        // After epoch 3: worker 0 read worker 1's value (13), and vice versa.
        assert_eq!(got, vec![13.0, 3.0]);
    }

    #[test]
    fn endpoint_halves_alternate_by_epoch_parity() {
        let mut pool = WorkerPool::new();
        let flags = EpochFlags::new(1);
        let acks = EpochFlags::new(1);
        let total = 1usize;
        let mut staging = vec![0.0f64; 2];
        let arena = ArenaView::new(&mut staging);
        pool.run(1, &|ctx| {
            // SAFETY: single worker, trivially disjoint.
            let mut ep =
                unsafe { PoolEndpoint::new(0, total, 2, &flags, &acks, &arena, &ctx) };
            ep.send_slot(1, 0..1)[0] = 1.5; // odd epoch → upper half
            ep.send_slot(2, 0..1)[0] = 2.5; // even epoch → lower half
        });
        assert_eq!(staging, vec![2.5, 1.5]);
    }

    #[test]
    fn endpoint_slots_rotate_by_epoch_mod_depth() {
        // A depth-3 arena: epochs 1..=3 land in slots 1, 2, 0.
        let mut pool = WorkerPool::new();
        let flags = EpochFlags::new(1);
        let acks = EpochFlags::new(1);
        let total = 1usize;
        let mut staging = vec![0.0f64; 3];
        let arena = ArenaView::new(&mut staging);
        pool.run(1, &|ctx| {
            // SAFETY: single worker, trivially disjoint.
            let mut ep =
                unsafe { PoolEndpoint::new(0, total, 3, &flags, &acks, &arena, &ctx) };
            ep.send_slot(1, 0..1)[0] = 1.5; // 1 mod 3 = slot 1
            ep.send_slot(2, 0..1)[0] = 2.5; // 2 mod 3 = slot 2
            ep.send_slot(3, 0..1)[0] = 3.5; // 3 mod 3 = slot 0
        });
        assert_eq!(staging, vec![3.5, 1.5, 2.5]);
    }
}
