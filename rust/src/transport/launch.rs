//! Multi-world orchestration: one compiled [`ExchangePlan`] executed over
//! any [`Transport`].
//!
//! This module closes the loop the transport layer opens: the *same*
//! workload — heat-2D, stencil-3D, or SpMV V3 — is described once by a
//! [`WorkloadSpec`], compiled once into an exchange plan, and then run in
//! any of three memory worlds:
//!
//! 1. **in-process reference** ([`run_reference`]) — the engine's
//!    sequential oracle, the bitwise ground truth;
//! 2. **in-process sockets** ([`run_socket_world`]) — one thread per rank
//!    over a loopback TCP mesh, same process;
//! 3. **multi-process sockets** ([`cmd_launch`] / [`worker_main`]) — the
//!    `repro launch --procs P` orchestrator spawns `P` worker *processes*,
//!    ships each the serialized plan (fingerprint-checked on arrival), and
//!    verifies fields and wire counters bitwise against world 1.
//!
//! [`ChaosAction`] injects a mid-run kill or stall into the highest rank so
//! the cross-process failure path (peer dies → reader marks the stream dead
//! → clean [`StallError`] within the deadline) is exercised end to end.
//! [`validate_transport`] closes the *model* loop: a socket ping-pong probe
//! parameterizes the τ/bandwidth terms, and measured per-step times for all
//! nine (workload × protocol) combinations are checked against the
//! predictions within a ratio budget.

use super::{
    loopback_mesh, socket_probe, wire, MeshStreams, ProcRuntime, SocketTransport, Transport,
};
use crate::comm::{refine_strided, Analysis, ExchangePlan, PlanOptimizer};
use crate::engine::{Engine, Phase, SpmvEngine, StallError};
use crate::heat2d::Heat2dSolver;
use crate::machine::{HwParams, TransportModel};
use crate::matrix::Ellpack;
use crate::model::{
    predict_heat2d_overlap_on, predict_stencil3d_overlap_on, predict_v3_overlap_on, HeatGrid,
    OverlapPrediction, PipelinePrediction, SpmvInputs,
};
use crate::pgas::{Layout, Topology};
use crate::spmv::{spmv_block_gathered, SpmvState, Variant};
use crate::stencil3d::{Stencil3dGrid, Stencil3dSolver};
use crate::util::json::{self, Value};
use crate::util::Rng;
use anyhow::{anyhow, bail, ensure};
use std::io;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// The three workloads every transport world must reproduce bitwise.
pub const WORKLOADS: [&str; 3] = ["heat", "stencil", "spmv"];

/// Scalars defining an SpMV V3 run (the matrix and layout are rebuilt
/// deterministically from the seeds on every rank).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpmvParams {
    pub n: usize,
    pub r_nz: usize,
    pub block: usize,
    pub procs: usize,
    pub mat_seed: u64,
    pub x_seed: u64,
}

/// A self-contained, serializable description of one workload instance:
/// enough to rebuild the geometry, the initial data, and — crucially — the
/// exchange plan on any rank of any world.
#[derive(Debug, Clone, Copy)]
pub enum WorkloadSpec {
    Heat { grid: HeatGrid, seed: u64 },
    Stencil { grid: Stencil3dGrid, seed: u64 },
    Spmv(SpmvParams),
}

impl WorkloadSpec {
    /// The default instance of workload `name` over `procs` ranks, sized so
    /// a loopback world finishes in well under a second per protocol.
    pub fn for_name(name: &str, procs: usize) -> Option<WorkloadSpec> {
        assert!(procs >= 1, "need at least one rank");
        match name {
            "heat" => Some(WorkloadSpec::Heat {
                grid: HeatGrid::new(32, 16 * procs, 1, procs),
                seed: 11,
            }),
            "stencil" => Some(WorkloadSpec::Stencil {
                grid: Stencil3dGrid::new(8, 8, 8 * procs, 1, 1, procs),
                seed: 7,
            }),
            "spmv" => Some(WorkloadSpec::Spmv(SpmvParams {
                n: 120 * procs,
                r_nz: 6,
                block: 30,
                procs,
                mat_seed: 5,
                x_seed: 23,
            })),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadSpec::Heat { .. } => "heat",
            WorkloadSpec::Stencil { .. } => "stencil",
            WorkloadSpec::Spmv(_) => "spmv",
        }
    }

    /// Number of ranks (= UPC threads) this instance is partitioned over.
    pub fn procs(&self) -> usize {
        match self {
            WorkloadSpec::Heat { grid, .. } => grid.threads(),
            WorkloadSpec::Stencil { grid, .. } => grid.threads(),
            WorkloadSpec::Spmv(p) => p.procs,
        }
    }

    /// Compile the exchange plan — the single artifact all worlds share.
    pub fn plan(&self) -> ExchangePlan {
        self.plan_with(PlanMode::Compiled)
    }

    /// Compile the `mode` variant of the exchange plan. All three variants
    /// carry the same (source cell → destination cell) assignments, so any
    /// world runs bitwise-identically on any of them; only message
    /// granularity, duplication, and arena order differ.
    pub fn plan_with(&self, mode: PlanMode) -> ExchangePlan {
        match mode {
            PlanMode::Compiled => match self {
                WorkloadSpec::Heat { grid, .. } => crate::heat2d::halo_plan(grid).into(),
                WorkloadSpec::Stencil { grid, .. } => crate::stencil3d::face_plan(grid).into(),
                WorkloadSpec::Spmv(p) => {
                    let (_, analysis) = spmv_setup(p);
                    analysis.plan.clone().into()
                }
            },
            PlanMode::Raw => match self {
                WorkloadSpec::Heat { grid, .. } => {
                    refine_strided(&crate::heat2d::halo_plan(grid)).into()
                }
                WorkloadSpec::Stencil { grid, .. } => {
                    refine_strided(&crate::stencil3d::face_plan(grid)).into()
                }
                WorkloadSpec::Spmv(p) => {
                    let m = Ellpack::random(p.n, p.r_nz, p.mat_seed);
                    let layout = Layout::new(p.n, p.block, p.procs);
                    Analysis::raw_gather_plan(&m.j, m.r_nz, &layout).into()
                }
            },
            // The default optimizer is deliberately calibration-free, so
            // every rank of every world compiles the identical optimized
            // plan (the launch-time fingerprint drift check depends on it).
            PlanMode::Optimized => {
                PlanOptimizer::default().optimize(&self.plan_with(PlanMode::Compiled))
            }
        }
    }

    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        match *self {
            WorkloadSpec::Heat { grid, seed } => {
                o.set("kind", Value::Str("heat".into()));
                o.set("m", Value::Num(grid.m_glob as f64));
                o.set("n", Value::Num(grid.n_glob as f64));
                o.set("mp", Value::Num(grid.mprocs as f64));
                o.set("np", Value::Num(grid.nprocs as f64));
                o.set("seed", Value::Num(seed as f64));
            }
            WorkloadSpec::Stencil { grid, seed } => {
                o.set("kind", Value::Str("stencil".into()));
                o.set("p", Value::Num(grid.p_glob as f64));
                o.set("m", Value::Num(grid.m_glob as f64));
                o.set("n", Value::Num(grid.n_glob as f64));
                o.set("pp", Value::Num(grid.pprocs as f64));
                o.set("mp", Value::Num(grid.mprocs as f64));
                o.set("np", Value::Num(grid.nprocs as f64));
                o.set("seed", Value::Num(seed as f64));
            }
            WorkloadSpec::Spmv(p) => {
                o.set("kind", Value::Str("spmv".into()));
                o.set("n", Value::Num(p.n as f64));
                o.set("r_nz", Value::Num(p.r_nz as f64));
                o.set("block", Value::Num(p.block as f64));
                o.set("procs", Value::Num(p.procs as f64));
                o.set("mat_seed", Value::Num(p.mat_seed as f64));
                o.set("x_seed", Value::Num(p.x_seed as f64));
            }
        }
        o
    }

    pub fn from_json(v: &Value) -> anyhow::Result<WorkloadSpec> {
        let kind = v.get("kind").and_then(Value::as_str).ok_or_else(|| anyhow!("spec: no kind"))?;
        match kind {
            "heat" => {
                let (m, n) = (field_usize(v, "m")?, field_usize(v, "n")?);
                let (mp, np) = (field_usize(v, "mp")?, field_usize(v, "np")?);
                ensure!(mp >= 1 && np >= 1 && m % mp == 0 && n % np == 0, "bad heat partition");
                Ok(WorkloadSpec::Heat {
                    grid: HeatGrid::new(m, n, mp, np),
                    seed: field_u64(v, "seed")?,
                })
            }
            "stencil" => {
                let (p, m, n) = (field_usize(v, "p")?, field_usize(v, "m")?, field_usize(v, "n")?);
                let (pp, mp, np) =
                    (field_usize(v, "pp")?, field_usize(v, "mp")?, field_usize(v, "np")?);
                ensure!(
                    pp >= 1 && mp >= 1 && np >= 1 && p % pp == 0 && m % mp == 0 && n % np == 0,
                    "bad stencil partition"
                );
                Ok(WorkloadSpec::Stencil {
                    grid: Stencil3dGrid::new(p, m, n, pp, mp, np),
                    seed: field_u64(v, "seed")?,
                })
            }
            "spmv" => {
                let p = SpmvParams {
                    n: field_usize(v, "n")?,
                    r_nz: field_usize(v, "r_nz")?,
                    block: field_usize(v, "block")?,
                    procs: field_usize(v, "procs")?,
                    mat_seed: field_u64(v, "mat_seed")?,
                    x_seed: field_u64(v, "x_seed")?,
                };
                ensure!(p.procs >= 1 && p.block >= 1 && p.n % p.block == 0, "bad spmv layout");
                Ok(WorkloadSpec::Spmv(p))
            }
            other => bail!("unknown workload kind '{other}'"),
        }
    }
}

fn field_usize(v: &Value, key: &str) -> anyhow::Result<usize> {
    v.get(key).and_then(Value::as_usize).ok_or_else(|| anyhow!("spec: missing '{key}'"))
}

fn field_u64(v: &Value, key: &str) -> anyhow::Result<u64> {
    let x = v.get(key).and_then(Value::as_f64).ok_or_else(|| anyhow!("spec: missing '{key}'"))?;
    ensure!(x >= 0.0 && x.fract() == 0.0, "spec: '{key}' is not a seed");
    Ok(x as u64)
}

/// Which variant of a workload's exchange plan a world runs
/// (`repro launch --plan`, `repro validate --optimize`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlanMode {
    /// The plan exactly as the workload compiles it: hand-written halo
    /// blocks, analyzer-condensed gather lists.
    #[default]
    Compiled,
    /// The fine-grained baseline the paper's enhancement three starts
    /// from: one message per cell on the strided side, occurrence-order
    /// duplicated gather lists on the gather side.
    Raw,
    /// The compiled plan run through the [`PlanOptimizer`] pass pipeline.
    Optimized,
}

impl PlanMode {
    pub const ALL: [PlanMode; 3] = [PlanMode::Compiled, PlanMode::Raw, PlanMode::Optimized];

    pub fn name(self) -> &'static str {
        match self {
            PlanMode::Compiled => "compiled",
            PlanMode::Raw => "raw",
            PlanMode::Optimized => "optimized",
        }
    }

    pub fn parse(s: &str) -> Option<PlanMode> {
        match s.to_ascii_lowercase().as_str() {
            "compiled" => Some(PlanMode::Compiled),
            "raw" => Some(PlanMode::Raw),
            "optimized" | "opt" => Some(PlanMode::Optimized),
            _ => None,
        }
    }
}

/// The three exchange protocols every transport must support.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Proto {
    /// Pack → publish → wait → unpack → ack → compute.
    Sync,
    /// Interior compute overlaps the in-flight halo (split-phase).
    Overlap,
    /// Multi-step pipeline bounded by the depth-2 consumed-epoch ack gate.
    Pipeline,
}

impl Proto {
    pub const ALL: [Proto; 3] = [Proto::Sync, Proto::Overlap, Proto::Pipeline];

    pub fn name(self) -> &'static str {
        match self {
            Proto::Sync => "sync",
            Proto::Overlap => "overlap",
            Proto::Pipeline => "pipeline",
        }
    }

    pub fn parse(s: &str) -> Option<Proto> {
        match s.to_ascii_lowercase().as_str() {
            "sync" => Some(Proto::Sync),
            "overlap" | "overlapped" => Some(Proto::Overlap),
            "pipeline" | "pipelined" => Some(Proto::Pipeline),
            _ => None,
        }
    }
}

/// A fault injected into the highest rank of a world: nothing, death at the
/// start of an epoch, or a stall (sleep) at the start of an epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosAction {
    None,
    /// Die at the start of this epoch (worker process: `exit(3)`;
    /// in-process world: drop the transport and return early).
    KillAt(u64),
    /// Sleep this long at the start of the epoch — long enough that every
    /// peer's wait deadline expires first.
    SlowAt(u64, Duration),
}

impl ChaosAction {
    /// Fire at epoch boundary `epoch`. Returns `false` when the rank should
    /// die now; the caller decides what death means in its world.
    pub fn fire(&self, epoch: u64) -> bool {
        match *self {
            ChaosAction::KillAt(e) if e == epoch => false,
            ChaosAction::SlowAt(e, d) if e == epoch => {
                std::thread::sleep(d);
                true
            }
            _ => true,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        match *self {
            ChaosAction::None => {
                o.set("kind", Value::Str("none".into()));
            }
            ChaosAction::KillAt(e) => {
                o.set("kind", Value::Str("kill".into()));
                o.set("epoch", Value::Num(e as f64));
            }
            ChaosAction::SlowAt(e, d) => {
                o.set("kind", Value::Str("slow".into()));
                o.set("epoch", Value::Num(e as f64));
                o.set("ms", Value::Num(d.as_millis() as f64));
            }
        }
        o
    }

    pub fn from_json(v: &Value) -> anyhow::Result<ChaosAction> {
        match v.get("kind").and_then(Value::as_str) {
            Some("none") | None => Ok(ChaosAction::None),
            Some("kill") => Ok(ChaosAction::KillAt(field_u64(v, "epoch")?)),
            Some("slow") => Ok(ChaosAction::SlowAt(
                field_u64(v, "epoch")?,
                Duration::from_millis(field_u64(v, "ms")?),
            )),
            Some(other) => bail!("unknown chaos kind '{other}'"),
        }
    }
}

/// What one rank hands back after driving its part of a world.
struct RankResult {
    field: Vec<f64>,
    bytes: u64,
    transfers: u64,
}

/// Drive one rank of `spec` over any transport, executing `plan` (which
/// must be the plan the transport was built around — any [`PlanMode`]
/// variant of the spec's plan). `Ok(None)` means the chaos action asked
/// this rank to die mid-run.
fn run_rank<T: Transport>(
    spec: &WorkloadSpec,
    plan: &ExchangePlan,
    proto: Proto,
    steps: u64,
    transport: T,
    chaos: &ChaosAction,
    depth: usize,
) -> Result<Option<RankResult>, StallError> {
    match *spec {
        WorkloadSpec::Heat { grid, seed } => {
            run_heat_rank(grid, seed, plan, proto, steps, transport, chaos, depth)
        }
        WorkloadSpec::Stencil { grid, seed } => {
            run_stencil_rank(grid, seed, plan, proto, steps, transport, chaos, depth)
        }
        WorkloadSpec::Spmv(p) => run_spmv_rank(&p, plan, proto, steps, transport, chaos, depth),
    }
}

/// Deterministic global initial data shared by every world.
fn seeded_field(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.f64_in(0.0, 100.0)).collect()
}

/// For a kill scheduled inside a pipelined run, the epochs that may run
/// first (`KillAt(e)` dies *at* `e`, so `e − 1` epochs complete).
fn pipeline_prefix(chaos: &ChaosAction, steps: u64) -> (u64, bool) {
    match *chaos {
        ChaosAction::KillAt(e) if e <= steps => (e - 1, true),
        _ => (steps, false),
    }
}

fn run_heat_rank<T: Transport>(
    grid: HeatGrid,
    seed: u64,
    plan: &ExchangePlan,
    proto: Proto,
    steps: u64,
    transport: T,
    chaos: &ChaosAction,
    depth: usize,
) -> Result<Option<RankResult>, StallError> {
    let rank = transport.rank();
    let (_, n) = grid.subdomain();
    let global = seeded_field(grid.m_glob * grid.n_glob, seed);
    let mut field = crate::heat2d::initial_field(grid, &global, rank);
    let mut out = field.clone();
    let split = crate::heat2d::compute_split(&grid);
    let mut rt = ProcRuntime::new(plan.clone(), transport);
    rt.set_depth(depth as u64);
    match proto {
        Proto::Sync => {
            for _ in 0..steps {
                if !chaos.fire(rt.epoch() + 1) {
                    return Ok(None);
                }
                rt.step_strided(&mut field, &mut out, |phi, phin| {
                    Heat2dSolver::jacobi_update(grid, rank, phi, phin);
                })?;
                std::mem::swap(&mut field, &mut out);
            }
        }
        Proto::Overlap => {
            for _ in 0..steps {
                if !chaos.fire(rt.epoch() + 1) {
                    return Ok(None);
                }
                rt.step_overlapped(
                    &mut field,
                    &mut out,
                    |phi, phin| crate::heat2d::jacobi_blocks(n, &split.interior, phi, phin),
                    |phi, phin| {
                        crate::heat2d::jacobi_blocks(n, &split.boundary, phi, phin);
                        Heat2dSolver::fixed_boundary_copy(grid, rank, phi, phin);
                    },
                )?;
                std::mem::swap(&mut field, &mut out);
            }
        }
        Proto::Pipeline => {
            let (run_steps, die_after) = pipeline_prefix(chaos, steps);
            if run_steps > 0 {
                rt.run_pipelined(
                    run_steps,
                    &mut field,
                    &mut out,
                    |phi, phin| crate::heat2d::jacobi_blocks(n, &split.interior, phi, phin),
                    |phi, phin| {
                        crate::heat2d::jacobi_blocks(n, &split.boundary, phi, phin);
                        Heat2dSolver::fixed_boundary_copy(grid, rank, phi, phin);
                    },
                    |e| {
                        let _ = chaos.fire(e);
                    },
                )?;
            }
            if die_after {
                return Ok(None);
            }
        }
    }
    let bytes = rt.transport().sent_payload_bytes();
    let transfers = rt.transport().sent_transfers();
    Ok(Some(RankResult { field, bytes, transfers }))
}

fn run_stencil_rank<T: Transport>(
    grid: Stencil3dGrid,
    seed: u64,
    plan: &ExchangePlan,
    proto: Proto,
    steps: u64,
    transport: T,
    chaos: &ChaosAction,
    depth: usize,
) -> Result<Option<RankResult>, StallError> {
    let rank = transport.rank();
    let (_, m, n) = grid.subdomain();
    let mn = m * n;
    let global = seeded_field(grid.p_glob * grid.m_glob * grid.n_glob, seed);
    let mut field = crate::stencil3d::initial_field(grid, &global, rank);
    let mut out = field.clone();
    let split = crate::stencil3d::compute_split(&grid);
    let mut rt = ProcRuntime::new(plan.clone(), transport);
    rt.set_depth(depth as u64);
    match proto {
        Proto::Sync => {
            for _ in 0..steps {
                if !chaos.fire(rt.epoch() + 1) {
                    return Ok(None);
                }
                rt.step_strided(&mut field, &mut out, |phi, phin| {
                    Stencil3dSolver::jacobi_update(grid, rank, phi, phin);
                })?;
                std::mem::swap(&mut field, &mut out);
            }
        }
        Proto::Overlap => {
            for _ in 0..steps {
                if !chaos.fire(rt.epoch() + 1) {
                    return Ok(None);
                }
                rt.step_overlapped(
                    &mut field,
                    &mut out,
                    |phi, phin| {
                        crate::stencil3d::jacobi_blocks3d(mn, n, &split.interior, phi, phin)
                    },
                    |phi, phin| {
                        crate::stencil3d::jacobi_blocks3d(mn, n, &split.boundary, phi, phin);
                        Stencil3dSolver::fixed_boundary_copy(grid, rank, phi, phin);
                    },
                )?;
                std::mem::swap(&mut field, &mut out);
            }
        }
        Proto::Pipeline => {
            let (run_steps, die_after) = pipeline_prefix(chaos, steps);
            if run_steps > 0 {
                rt.run_pipelined(
                    run_steps,
                    &mut field,
                    &mut out,
                    |phi, phin| {
                        crate::stencil3d::jacobi_blocks3d(mn, n, &split.interior, phi, phin)
                    },
                    |phi, phin| {
                        crate::stencil3d::jacobi_blocks3d(mn, n, &split.boundary, phi, phin);
                        Stencil3dSolver::fixed_boundary_copy(grid, rank, phi, phin);
                    },
                    |e| {
                        let _ = chaos.fire(e);
                    },
                )?;
            }
            if die_after {
                return Ok(None);
            }
        }
    }
    let bytes = rt.transport().sent_payload_bytes();
    let transfers = rt.transport().sent_transfers();
    Ok(Some(RankResult { field, bytes, transfers }))
}

/// Rebuild the deterministic SpMV problem every world shares: matrix,
/// per-thread state, and the V3 communication analysis.
fn spmv_setup(p: &SpmvParams) -> (SpmvState, Analysis) {
    let m = Ellpack::random(p.n, p.r_nz, p.mat_seed);
    let x0 = m.initial_vector(p.x_seed);
    let state = SpmvState::new(&m, p.block, p.procs, &x0);
    let analysis = Analysis::build(
        &m.j,
        m.r_nz,
        state.layout,
        Topology::single_node(p.procs),
        usize::MAX,
    );
    (state, analysis)
}

/// Drive one rank of the gather-form SpMV V3 exchange directly over the
/// transport (the strided `ProcRuntime` does not apply here): per epoch,
/// pack → publish → own-block copy → [interior] → wait → scatter → ack →
/// compute → swap. The FP op order matches the engine's V3 arms exactly, so
/// results are bitwise identical to the in-process reference.
fn run_spmv_rank<T: Transport>(
    p: &SpmvParams,
    plan: &ExchangePlan,
    proto: Proto,
    steps: u64,
    mut transport: T,
    chaos: &ChaosAction,
    depth: usize,
) -> Result<Option<RankResult>, StallError> {
    let depth = depth as u64;
    let rank = transport.rank();
    let (state, analysis) = spmv_setup(p);
    let layout = state.layout;
    let bs = layout.block_size;
    let r_nz = state.r_nz;
    let plan = plan.as_gather().expect("spmv runs a gather plan");
    let mut src: Vec<f64> = state.x.local(rank).to_vec();
    let mut dst: Vec<f64> = state.y.local(rank).to_vec();
    let mut ws = vec![0.0f64; layout.n];
    let mut from: Vec<usize> = plan.recv_msgs(rank).map(|m| m.peer as usize).collect();
    from.sort_unstable();
    from.dedup();
    let mut to: Vec<usize> = plan.send_msgs(rank).map(|m| m.peer as usize).collect();
    to.sort_unstable();
    to.dedup();
    for e in 1..=steps {
        if !chaos.fire(e) {
            return Ok(None);
        }
        if proto == Proto::Pipeline && e > depth {
            for &peer in &to {
                transport.wait_for_ack(peer, e - depth)?;
            }
        }
        for m in plan.send_msgs(rank) {
            let buf = transport.send_slot(e, m.range());
            for (slot, &off) in buf.iter_mut().zip(m.local_src) {
                *slot = src[off as usize];
            }
        }
        transport.publish(e)?;
        for b in layout.blocks_of_thread(rank) {
            let (start, len) = layout.block_range(b);
            let mb = layout.local_block_index(b);
            ws[start..start + len].copy_from_slice(&src[mb * bs..mb * bs + len]);
        }
        if proto != Proto::Sync {
            crate::engine::compute_row_runs(
                &layout,
                r_nz,
                &state.d,
                &state.a,
                &state.j,
                &analysis.row_split[rank].interior,
                &ws,
                &mut dst,
            );
        }
        for &peer in &from {
            transport.wait_for_epoch(peer, e)?;
        }
        for m in plan.recv_msgs(rank) {
            let vals = transport.recv_slot(e, m.range());
            for (&gidx, &v) in m.indices.iter().zip(vals) {
                ws[gidx as usize] = v;
            }
        }
        transport.ack(e)?;
        match proto {
            Proto::Sync => {
                for b in layout.blocks_of_thread(rank) {
                    let (start, len) = layout.block_range(b);
                    let mb = layout.local_block_index(b);
                    spmv_block_gathered(
                        start,
                        state.d.block(b),
                        state.a.block(b),
                        state.j.block(b),
                        r_nz,
                        &ws,
                        &mut dst[mb * bs..mb * bs + len],
                    );
                }
            }
            _ => {
                crate::engine::compute_row_runs(
                    &layout,
                    r_nz,
                    &state.d,
                    &state.a,
                    &state.j,
                    &analysis.row_split[rank].boundary,
                    &ws,
                    &mut dst,
                );
            }
        }
        std::mem::swap(&mut src, &mut dst);
    }
    let bytes = transport.sent_payload_bytes();
    let transfers = transport.sent_transfers();
    Ok(Some(RankResult { field: src, bytes, transfers }))
}

/// The outcome of running one world: per-rank final fields (empty for a
/// rank that died), summed wire counters, wall time, and any stalls.
#[derive(Debug)]
pub struct WorldOutcome {
    /// Final per-rank local fields (heat/stencil: the `phi` storage incl.
    /// halo; SpMV: the rank's shard of the final iterate).
    pub fields: Vec<Vec<f64>>,
    /// Payload bytes that crossed rank boundaries, summed over ranks.
    pub bytes: u64,
    /// Plan messages sent, summed over ranks.
    pub transfers: u64,
    pub elapsed: Duration,
    /// `(rank, error)` for every rank that raised a [`StallError`].
    pub stalls: Vec<(usize, String)>,
    /// Ranks the chaos action killed mid-run.
    pub killed: Vec<usize>,
}

/// World 1: the engine's in-process sequential oracle. Ground truth for
/// fields *and* for the wire counters (payload bytes cross the same plan
/// edges no matter which memory world carries them).
pub fn run_reference(spec: &WorkloadSpec, proto: Proto, steps: u64) -> WorldOutcome {
    run_reference_mode(spec, proto, steps, PlanMode::Compiled)
}

/// [`run_reference`] executing the `mode` variant of the spec's plan — the
/// in-process half of the optimized-vs-raw equivalence matrix.
pub fn run_reference_mode(
    spec: &WorkloadSpec,
    proto: Proto,
    steps: u64,
    mode: PlanMode,
) -> WorldOutcome {
    let plan = spec.plan_with(mode);
    let t0 = Instant::now();
    match *spec {
        WorkloadSpec::Heat { grid, seed } => {
            let global = seeded_field(grid.m_glob * grid.n_glob, seed);
            let strided = plan.as_strided().expect("heat runs a strided plan").clone();
            let mut solver = Heat2dSolver::with_plan(grid, &global, strided);
            match proto {
                Proto::Sync => {
                    for _ in 0..steps {
                        solver.step_with(Engine::Sequential);
                    }
                }
                Proto::Overlap => {
                    for _ in 0..steps {
                        solver.step_overlapped_with(Engine::Sequential);
                    }
                }
                Proto::Pipeline => solver.run_pipelined_with(Engine::Sequential, steps as usize),
            }
            let transfers = steps * solver.runtime().plan().num_messages() as u64;
            WorldOutcome {
                fields: solver.local_fields().to_vec(),
                bytes: solver.inter_thread_bytes,
                transfers,
                elapsed: t0.elapsed(),
                stalls: Vec::new(),
                killed: Vec::new(),
            }
        }
        WorkloadSpec::Stencil { grid, seed } => {
            let global = seeded_field(grid.p_glob * grid.m_glob * grid.n_glob, seed);
            let strided = plan.as_strided().expect("stencil runs a strided plan").clone();
            let mut solver = Stencil3dSolver::with_plan(grid, &global, strided);
            match proto {
                Proto::Sync => {
                    for _ in 0..steps {
                        solver.step_with(Engine::Sequential);
                    }
                }
                Proto::Overlap => {
                    for _ in 0..steps {
                        solver.step_overlapped_with(Engine::Sequential);
                    }
                }
                Proto::Pipeline => solver.run_pipelined_with(Engine::Sequential, steps as usize),
            }
            let transfers = steps * solver.runtime().plan().num_messages() as u64;
            WorldOutcome {
                fields: solver.local_fields().to_vec(),
                bytes: solver.inter_thread_bytes,
                transfers,
                elapsed: t0.elapsed(),
                stalls: Vec::new(),
                killed: Vec::new(),
            }
        }
        WorkloadSpec::Spmv(p) => {
            let (mut state, mut analysis) = spmv_setup(&p);
            analysis.plan = plan.as_gather().expect("spmv runs a gather plan").clone();
            let mut engine = SpmvEngine::new(Engine::Sequential);
            let mut bytes = 0u64;
            let mut transfers = 0u64;
            match proto {
                Proto::Sync => {
                    for _ in 0..steps {
                        let out = engine.run(Variant::V3, &mut state, Some(&analysis));
                        bytes += out.inter_thread_bytes;
                        transfers += out.transfers;
                        state.swap_xy();
                    }
                }
                Proto::Overlap => {
                    for _ in 0..steps {
                        let out = engine.run_overlapped(&mut state, &analysis);
                        bytes += out.inter_thread_bytes;
                        transfers += out.transfers;
                        state.swap_xy();
                    }
                }
                Proto::Pipeline => {
                    let out = engine.run_pipelined(steps as usize, &mut state, &analysis);
                    bytes += out.inter_thread_bytes;
                    transfers += out.transfers;
                }
            }
            // Sync/overlap end with `swap_xy`, leaving the final iterate in
            // `x`; a pipelined batch leaves it in `y`.
            let fields = (0..p.procs)
                .map(|t| match proto {
                    Proto::Pipeline => state.y.local(t).to_vec(),
                    _ => state.x.local(t).to_vec(),
                })
                .collect();
            WorldOutcome {
                fields,
                bytes,
                transfers,
                elapsed: t0.elapsed(),
                stalls: Vec::new(),
                killed: Vec::new(),
            }
        }
    }
}

fn io_stall(rank: usize, err: &io::Error) -> StallError {
    StallError {
        waiter: rank,
        peer: None,
        epoch: 0,
        phase: Phase::Idle,
        waited: Duration::ZERO,
        transport: Some(format!("socket setup: {err}")),
    }
}

/// World 2: one thread per rank over a loopback TCP mesh, all in this
/// process. `chaos` (if any) is injected into the highest rank.
pub fn run_socket_world(
    spec: &WorkloadSpec,
    proto: Proto,
    steps: u64,
    deadline: Option<Duration>,
    chaos: ChaosAction,
) -> io::Result<WorldOutcome> {
    run_socket_world_mode(spec, proto, steps, deadline, chaos, PlanMode::Compiled)
}

/// [`run_socket_world`] executing the `mode` variant of the spec's plan.
/// The transport and every rank's runtime are built around the *same*
/// compiled plan, so arena ranges agree by construction.
pub fn run_socket_world_mode(
    spec: &WorkloadSpec,
    proto: Proto,
    steps: u64,
    deadline: Option<Duration>,
    chaos: ChaosAction,
    mode: PlanMode,
) -> io::Result<WorldOutcome> {
    run_socket_world_depth(spec, proto, steps, deadline, chaos, mode, 2)
}

/// [`run_socket_world_mode`] with an explicit pipeline depth D: every
/// rank's transport arena holds `depth` buffered slots and the pipelined
/// ack gate waits on epoch `e − D`. Depth never changes results — only how
/// much sender/receiver skew the socket world absorbs.
pub fn run_socket_world_depth(
    spec: &WorkloadSpec,
    proto: Proto,
    steps: u64,
    deadline: Option<Duration>,
    chaos: ChaosAction,
    mode: PlanMode,
    depth: usize,
) -> io::Result<WorldOutcome> {
    let procs = spec.procs();
    let plan = spec.plan_with(mode);
    let mesh = loopback_mesh(procs)?;
    let t0 = Instant::now();
    let results: Vec<Result<Option<RankResult>, StallError>> = std::thread::scope(|s| {
        let handles: Vec<_> = mesh
            .into_iter()
            .enumerate()
            .map(|(rank, row)| {
                let plan = &plan;
                let spec = *spec;
                s.spawn(move || {
                    let transport = SocketTransport::with_depth(rank, plan, row, deadline, depth)
                        .map_err(|e| io_stall(rank, &e))?;
                    let ch = if rank == procs - 1 { chaos } else { ChaosAction::None };
                    run_rank(&spec, plan, proto, steps, transport, &ch, depth)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let elapsed = t0.elapsed();
    let mut out = WorldOutcome {
        fields: vec![Vec::new(); procs],
        bytes: 0,
        transfers: 0,
        elapsed,
        stalls: Vec::new(),
        killed: Vec::new(),
    };
    for (rank, res) in results.into_iter().enumerate() {
        match res {
            Ok(Some(rr)) => {
                out.bytes += rr.bytes;
                out.transfers += rr.transfers;
                out.fields[rank] = rr.field;
            }
            Ok(None) => out.killed.push(rank),
            Err(e) => out.stalls.push((rank, e.to_string())),
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// World 3: multi-process over real sockets (`repro launch`).
// ---------------------------------------------------------------------------

/// Exit code a worker uses when the chaos action kills it — the leader
/// treats exactly this code as a planned death.
pub const CHAOS_EXIT_CODE: i32 = 3;

/// Configuration of one `repro launch` run.
#[derive(Debug, Clone)]
pub struct LaunchConfig {
    pub procs: usize,
    pub workload: String,
    pub proto: Proto,
    pub steps: u64,
    /// Pipeline depth D shipped to every worker: buffered staging slots in
    /// each rank's transport arena, and the `e − D` ack-gate distance of
    /// the pipelined protocol (`--depth`, default 2).
    pub depth: usize,
    /// Per-wait stall deadline shipped to every worker.
    pub deadline: Duration,
    pub chaos: ChaosAction,
    /// Which plan variant every rank compiles and runs (`--plan`).
    pub plan_mode: PlanMode,
    /// Verify fields and counters bitwise against [`run_reference`].
    pub verify: bool,
}

enum WorkerReport {
    Finished { bytes: u64, transfers: u64, field: Vec<f64> },
    Stalled(String),
    Dead(String),
}

/// Accept one connection, polling so a dead peer cannot hang us forever.
fn accept_with_deadline(listener: &TcpListener, deadline: Instant) -> anyhow::Result<TcpStream> {
    listener.set_nonblocking(true)?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                listener.set_nonblocking(false)?;
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                return Ok(s);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                ensure!(Instant::now() < deadline, "accept timed out waiting for a peer");
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// The `repro launch --procs P` orchestrator: spawn `P` worker processes,
/// ship each the serialized plan + spec, collect per-rank results, and
/// verify them against the in-process reference.
pub fn cmd_launch(cfg: &LaunchConfig) -> anyhow::Result<()> {
    let spec = WorkloadSpec::for_name(&cfg.workload, cfg.procs).ok_or_else(|| {
        anyhow!("unknown workload '{}' (expected one of {:?})", cfg.workload, WORKLOADS)
    })?;
    let plan = spec.plan_with(cfg.plan_mode);
    let fp = plan.fingerprint();
    println!(
        "launch: {} / {} x{} over {} procs (depth {}), {} plan {:016x} ({} values, {} msgs per epoch)",
        spec.name(),
        cfg.proto.name(),
        cfg.steps,
        cfg.procs,
        cfg.depth,
        cfg.plan_mode.name(),
        fp,
        plan.total_values(),
        plan.num_messages()
    );

    let listener = TcpListener::bind("127.0.0.1:0")?;
    let leader_addr = listener.local_addr()?;
    let exe = std::env::current_exe()?;
    let mut children = Vec::with_capacity(cfg.procs);
    for r in 0..cfg.procs {
        let child = std::process::Command::new(&exe)
            .arg("_worker")
            .arg("--rank")
            .arg(r.to_string())
            .arg("--procs")
            .arg(cfg.procs.to_string())
            .arg("--connect")
            .arg(leader_addr.to_string())
            .spawn()?;
        children.push(child);
    }

    // Phase 1: collect hellos (rank + the worker's own mesh address).
    let handshake_deadline = Instant::now() + Duration::from_secs(60);
    let mut conns: Vec<Option<TcpStream>> = (0..cfg.procs).map(|_| None).collect();
    let mut addrs: Vec<String> = vec![String::new(); cfg.procs];
    for _ in 0..cfg.procs {
        let mut s = accept_with_deadline(&listener, handshake_deadline)?;
        s.set_read_timeout(Some(Duration::from_secs(20)))?;
        let hello = wire::read_msg(&mut s)?;
        let v = json::parse(std::str::from_utf8(&hello)?)?;
        let r = field_usize(&v, "rank")?;
        let a = v.get("addr").and_then(Value::as_str).ok_or_else(|| anyhow!("bad hello"))?;
        ensure!(r < cfg.procs && conns[r].is_none(), "duplicate or out-of-range hello rank {r}");
        addrs[r] = a.to_string();
        conns[r] = Some(s);
    }

    // Phase 2: ship each worker the spec, the compiled plan, and the mesh.
    let mut base = Value::obj();
    base.set("workload", spec.to_json());
    base.set("proto", Value::Str(cfg.proto.name().into()));
    base.set("steps", Value::Num(cfg.steps as f64));
    base.set("depth", Value::Num(cfg.depth as f64));
    base.set("deadline_ms", Value::Num(cfg.deadline.as_millis() as f64));
    base.set("plan", plan.to_json());
    base.set("plan_fp", Value::Str(format!("{fp:016x}")));
    base.set("plan_mode", Value::Str(cfg.plan_mode.name().into()));
    base.set("addrs", Value::Arr(addrs.iter().map(|a| Value::Str(a.clone())).collect()));
    for (r, conn) in conns.iter_mut().enumerate() {
        let chaos = if r == cfg.procs - 1 { cfg.chaos } else { ChaosAction::None };
        let mut msg = base.clone();
        msg.set("chaos", chaos.to_json());
        wire::write_msg(conn.as_mut().unwrap(), msg.compact().as_bytes())?;
    }

    // Phase 3: collect results. A slow-chaos victim reports only after its
    // injected sleep (3 deadlines by convention), so allow generous slack.
    let result_timeout = cfg.deadline * 8 + Duration::from_secs(20);
    let mut reports = Vec::with_capacity(cfg.procs);
    for conn in conns.iter_mut() {
        let s = conn.as_mut().unwrap();
        s.set_read_timeout(Some(result_timeout))?;
        let rep = match wire::read_msg(s) {
            Ok(head) => read_report(s, &head)?,
            Err(e) => WorkerReport::Dead(e.to_string()),
        };
        reports.push(rep);
    }

    // Phase 4: reap children (kill stragglers rather than hang).
    let mut exit_codes: Vec<Option<i32>> = Vec::with_capacity(cfg.procs);
    for mut child in children {
        let reap_deadline = Instant::now() + Duration::from_secs(15);
        let status = loop {
            match child.try_wait()? {
                Some(st) => break Some(st),
                None if Instant::now() >= reap_deadline => {
                    let _ = child.kill();
                    break child.wait().ok();
                }
                None => std::thread::sleep(Duration::from_millis(25)),
            }
        };
        exit_codes.push(status.and_then(|st| st.code()));
    }

    evaluate_launch(cfg, &spec, &reports, &exit_codes)
}

fn read_report(s: &mut TcpStream, head: &[u8]) -> anyhow::Result<WorkerReport> {
    let v = json::parse(std::str::from_utf8(head)?)?;
    match v.get("status").and_then(Value::as_str) {
        Some("ok") => {
            let bytes = v.get("bytes").and_then(Value::as_f64).unwrap_or(0.0) as u64;
            let transfers = v.get("transfers").and_then(Value::as_f64).unwrap_or(0.0) as u64;
            let field = wire::bytes_to_f64s(&wire::read_msg(s)?);
            Ok(WorkerReport::Finished { bytes, transfers, field })
        }
        Some("stall") => Ok(WorkerReport::Stalled(
            v.get("error").and_then(Value::as_str).unwrap_or("unknown stall").to_string(),
        )),
        other => bail!("worker sent unknown status {other:?}"),
    }
}

fn evaluate_launch(
    cfg: &LaunchConfig,
    spec: &WorkloadSpec,
    reports: &[WorkerReport],
    exit_codes: &[Option<i32>],
) -> anyhow::Result<()> {
    let victim = cfg.procs - 1;
    match cfg.chaos {
        ChaosAction::None => {
            let mut fields = Vec::with_capacity(cfg.procs);
            let mut bytes = 0u64;
            let mut transfers = 0u64;
            for (r, rep) in reports.iter().enumerate() {
                match rep {
                    WorkerReport::Finished { bytes: b, transfers: t, field } => {
                        bytes += b;
                        transfers += t;
                        fields.push(field.clone());
                    }
                    WorkerReport::Stalled(e) => bail!("rank {r} stalled: {e}"),
                    WorkerReport::Dead(e) => {
                        bail!("rank {r} died ({e}); exit code {:?}", exit_codes[r])
                    }
                }
            }
            println!(
                "all {} ranks finished: {bytes} payload bytes, {transfers} transfers",
                cfg.procs
            );
            if cfg.verify {
                let reference = run_reference_mode(spec, cfg.proto, cfg.steps, cfg.plan_mode);
                ensure!(
                    bytes == reference.bytes,
                    "payload bytes diverge: sockets {bytes} vs in-process {}",
                    reference.bytes
                );
                ensure!(
                    transfers == reference.transfers,
                    "transfers diverge: sockets {transfers} vs in-process {}",
                    reference.transfers
                );
                for (r, (got, want)) in fields.iter().zip(&reference.fields).enumerate() {
                    ensure!(
                        got.len() == want.len(),
                        "rank {r}: field length {} vs reference {}",
                        got.len(),
                        want.len()
                    );
                    let bad =
                        got.iter().zip(want.iter()).position(|(a, b)| a.to_bits() != b.to_bits());
                    if let Some(i) = bad {
                        bail!(
                            "rank {r}: field diverges from the in-process reference at [{i}]: \
                             {} vs {}",
                            got[i],
                            want[i]
                        );
                    }
                }
                println!("verified bitwise against the in-process reference");
            }
        }
        ChaosAction::KillAt(e) => {
            ensure!(
                exit_codes[victim] == Some(CHAOS_EXIT_CODE)
                    || matches!(reports[victim], WorkerReport::Dead(_)),
                "rank {victim} should have died at epoch {e} (exit {:?})",
                exit_codes[victim]
            );
            for (r, rep) in reports.iter().enumerate().filter(|(r, _)| *r != victim) {
                match rep {
                    WorkerReport::Stalled(msg) => println!("rank {r} contained the fault: {msg}"),
                    WorkerReport::Finished { .. } => {
                        bail!("rank {r} finished despite rank {victim} dying at epoch {e}")
                    }
                    WorkerReport::Dead(err) => bail!("rank {r} died instead of stalling: {err}"),
                }
            }
            println!(
                "chaos kill at epoch {e}: rank {victim} died (exit {:?}), \
                 all survivors stalled cleanly",
                exit_codes[victim]
            );
        }
        ChaosAction::SlowAt(e, d) => {
            for (r, rep) in reports.iter().enumerate() {
                match rep {
                    WorkerReport::Stalled(msg) => println!("rank {r} stalled cleanly: {msg}"),
                    WorkerReport::Finished { .. } if r == victim => {
                        println!("rank {r} (the slowed rank) finished after its {d:?} nap")
                    }
                    WorkerReport::Finished { .. } => {
                        bail!("rank {r} finished despite the rank-{victim} stall at epoch {e}")
                    }
                    WorkerReport::Dead(err) => bail!("rank {r} died instead of stalling: {err}"),
                }
            }
            println!("chaos slow at epoch {e} ({d:?}): every healthy rank stalled in time");
        }
    }
    Ok(())
}

/// Entry point for a spawned worker process (`repro _worker --rank R
/// --procs P --connect ADDR`). Never invoked by users directly.
pub fn worker_main(args: &[String]) -> anyhow::Result<()> {
    let mut rank = None;
    let mut procs = None;
    let mut connect = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--rank" => rank = it.next().and_then(|s| s.parse::<usize>().ok()),
            "--procs" => procs = it.next().and_then(|s| s.parse::<usize>().ok()),
            "--connect" => connect = it.next().cloned(),
            other => bail!("unknown _worker arg '{other}'"),
        }
    }
    let rank = rank.ok_or_else(|| anyhow!("_worker: missing --rank"))?;
    let procs = procs.ok_or_else(|| anyhow!("_worker: missing --procs"))?;
    let connect = connect.ok_or_else(|| anyhow!("_worker: missing --connect"))?;
    ensure!(rank < procs, "_worker: rank {rank} out of range for {procs} procs");
    worker_run(rank, procs, &connect)
}

fn worker_run(rank: usize, procs: usize, connect: &str) -> anyhow::Result<()> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let my_addr = listener.local_addr()?;
    let mut leader = TcpStream::connect(connect)?;
    leader.set_nodelay(true)?;
    let mut hello = Value::obj();
    hello.set("rank", Value::Num(rank as f64));
    hello.set("addr", Value::Str(my_addr.to_string()));
    wire::write_msg(&mut leader, hello.compact().as_bytes())?;

    leader.set_read_timeout(Some(Duration::from_secs(60)))?;
    let spec_bytes = wire::read_msg(&mut leader)?;
    leader.set_read_timeout(None)?;
    let v = json::parse(std::str::from_utf8(&spec_bytes)?)?;
    let spec =
        WorkloadSpec::from_json(v.get("workload").ok_or_else(|| anyhow!("spec: no workload"))?)?;
    let proto = v
        .get("proto")
        .and_then(Value::as_str)
        .and_then(Proto::parse)
        .ok_or_else(|| anyhow!("spec: bad proto"))?;
    let steps = field_u64(&v, "steps")?;
    // Older leaders do not ship a depth; fall back to the historical 2.
    let depth = v
        .get("depth")
        .and_then(Value::as_f64)
        .map(|d| d as usize)
        .filter(|&d| d >= 1)
        .unwrap_or(2);
    let deadline = Duration::from_millis(field_u64(&v, "deadline_ms")?);
    let chaos = match v.get("chaos") {
        Some(c) => ChaosAction::from_json(c)?,
        None => ChaosAction::None,
    };
    let plan_mode = match v.get("plan_mode") {
        None => PlanMode::Compiled,
        Some(m) => m
            .as_str()
            .and_then(PlanMode::parse)
            .ok_or_else(|| anyhow!("spec: bad plan_mode"))?,
    };

    // The shipped plan must be intact (fingerprint check) *and* agree with
    // the plan this rank would compile from the spec itself under the same
    // mode — any drift between worlds (including an optimizer that is not
    // deterministic across processes) is a protocol error, not a numerics
    // error.
    let fp_hex = v.get("plan_fp").and_then(Value::as_str).ok_or_else(|| anyhow!("no plan_fp"))?;
    let shipped_fp = u64::from_str_radix(fp_hex, 16)?;
    let shipped_plan = ExchangePlan::from_json(v.get("plan").ok_or_else(|| anyhow!("no plan"))?)
        .map_err(|e| anyhow!("shipped plan rejected: {e}"))?;
    ensure!(
        shipped_plan.fingerprint() == shipped_fp,
        "shipped plan corrupt: fingerprint {:016x} vs header {:016x}",
        shipped_plan.fingerprint(),
        shipped_fp
    );
    let local_fp = spec.plan_with(plan_mode).fingerprint();
    ensure!(
        local_fp == shipped_fp,
        "plan drift: locally compiled {} plan {local_fp:016x} vs shipped {shipped_fp:016x}",
        plan_mode.name()
    );
    let addrs: Vec<String> = v
        .get("addrs")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("no addrs"))?
        .iter()
        .filter_map(|a| a.as_str().map(str::to_string))
        .collect();
    ensure!(addrs.len() == procs, "addr list has {} entries, want {procs}", addrs.len());

    // Mesh up: connect to every lower rank (sending a HELLO frame so the
    // acceptor learns who we are), accept from every higher rank.
    let mut row: MeshStreams = (0..procs).map(|_| None).collect();
    for (j, addr) in addrs.iter().enumerate().take(rank) {
        let mut s = TcpStream::connect(addr)?;
        s.set_nodelay(true)?;
        wire::write_frame(&mut s, wire::KIND_HELLO, rank as u32, 0, 0, &[])?;
        row[j] = Some(s);
    }
    let mesh_deadline = Instant::now() + Duration::from_secs(60);
    for _ in rank + 1..procs {
        let mut s = accept_with_deadline(&listener, mesh_deadline)?;
        s.set_read_timeout(Some(Duration::from_secs(60)))?;
        let f = wire::read_frame(&mut s)?;
        ensure!(f.kind == wire::KIND_HELLO, "expected HELLO during mesh handshake");
        let peer = f.sender as usize;
        ensure!(peer > rank && peer < procs && row[peer].is_none(), "bad mesh HELLO from {peer}");
        // Clear the handshake timeout: the transport's reader threads rely
        // on blocking reads (a timeout would read as a dead peer).
        s.set_read_timeout(None)?;
        row[peer] = Some(s);
    }

    let transport = SocketTransport::with_depth(rank, &shipped_plan, row, Some(deadline), depth)?;
    match run_rank(&spec, &shipped_plan, proto, steps, transport, &chaos, depth) {
        Ok(Some(rr)) => {
            let mut res = Value::obj();
            res.set("status", Value::Str("ok".into()));
            res.set("bytes", Value::Num(rr.bytes as f64));
            res.set("transfers", Value::Num(rr.transfers as f64));
            wire::write_msg(&mut leader, res.compact().as_bytes())?;
            wire::write_msg(&mut leader, &wire::f64s_to_bytes(&rr.field))?;
            Ok(())
        }
        Ok(None) => {
            eprintln!("worker {rank}: chaos kill at work, dying");
            std::process::exit(CHAOS_EXIT_CODE);
        }
        Err(stall) => {
            eprintln!("worker {rank}: {stall}");
            let mut res = Value::obj();
            res.set("status", Value::Str("stall".into()));
            res.set("error", Value::Str(stall.to_string()));
            wire::write_msg(&mut leader, res.compact().as_bytes())?;
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Model validation over the socket transport.
// ---------------------------------------------------------------------------

/// One measured-vs-predicted row of `repro validate --transport socket`.
#[derive(Debug, Clone)]
pub struct TransportRow {
    pub workload: &'static str,
    pub proto: Proto,
    /// Measured seconds per step over the loopback socket world.
    pub measured: f64,
    /// Model prediction with the socket-probe τ/bandwidth substituted.
    pub predicted: f64,
}

impl TransportRow {
    pub fn ratio(&self) -> f64 {
        self.measured / self.predicted
    }
}

/// Resolve `--depth auto` for one launch workload: the
/// [`choose_depth`](crate::model::choose_depth) sweep over this workload's
/// overlap prediction with the transport's latency/bandwidth substituted —
/// one advisory pick per plan × transport, exactly what the drill runs.
pub fn auto_depth(spec: &WorkloadSpec, steps: usize, tm: &TransportModel) -> usize {
    let op = overlap_prediction_for(spec, tm);
    let tau = tm.apply(&HwParams::abel()).tau;
    crate::model::choose_depth(&op, steps.max(1), tau).0
}

fn overlap_prediction_for(spec: &WorkloadSpec, tm: &TransportModel) -> OverlapPrediction {
    let hw = HwParams::abel();
    // One rank per node: every plan edge crosses the modeled interconnect,
    // matching what the socket world actually does.
    let topo = Topology::new(spec.procs(), 1);
    match *spec {
        WorkloadSpec::Heat { grid, .. } => predict_heat2d_overlap_on(tm, &grid, &topo, &hw),
        WorkloadSpec::Stencil { grid, .. } => predict_stencil3d_overlap_on(tm, &grid, &topo, &hw),
        WorkloadSpec::Spmv(p) => {
            let (state, analysis) = spmv_setup(&p);
            let inputs =
                SpmvInputs { layout: state.layout, topo, hw, r_nz: p.r_nz, analysis: &analysis };
            predict_v3_overlap_on(tm, &inputs)
        }
    }
}

/// Measure all nine (workload × protocol) per-step times over the loopback
/// socket world and compare each against the transport-parameterized model.
/// The `BENCH_transport.json` artifact is written *before* the budget gate,
/// so a failing run still leaves its evidence behind.
pub fn validate_transport(
    procs: usize,
    steps: u64,
    quick: bool,
    budget: f64,
) -> anyhow::Result<Vec<TransportRow>> {
    ensure!(procs >= 2, "transport validation needs at least 2 ranks");
    ensure!(steps >= 1 && budget > 1.0, "need steps >= 1 and budget > 1");
    let probe = socket_probe(quick).map_err(|e| anyhow!("socket probe failed: {e}"))?;
    let tm = TransportModel::socket(probe.latency, probe.bandwidth);
    println!(
        "socket probe: latency {:.2} us, bandwidth {:.0} MB/s",
        probe.latency * 1e6,
        probe.bandwidth / 1e6
    );
    let deadline = Some(Duration::from_secs(30));
    let mut rows = Vec::with_capacity(WORKLOADS.len() * Proto::ALL.len());
    for name in WORKLOADS {
        let spec = WorkloadSpec::for_name(name, procs).unwrap();
        let op = overlap_prediction_for(&spec, &tm);
        for proto in Proto::ALL {
            let world = run_socket_world(&spec, proto, steps, deadline, ChaosAction::None)
                .map_err(|e| anyhow!("{name}/{}: socket world failed: {e}", proto.name()))?;
            ensure!(
                world.stalls.is_empty() && world.killed.is_empty(),
                "{name}/{}: unexpected stalls {:?}",
                proto.name(),
                world.stalls
            );
            let measured = world.elapsed.as_secs_f64() / steps as f64;
            let predicted = match proto {
                Proto::Sync => op.t_step_sync,
                Proto::Overlap => op.t_step,
                Proto::Pipeline => PipelinePrediction::from_overlap(&op, steps as usize).t_per_step,
            };
            rows.push(TransportRow { workload: name, proto, measured, predicted });
        }
    }

    println!(
        "{:<9} {:<9} {:>13} {:>13} {:>9}",
        "workload", "proto", "measured/s", "predicted/s", "ratio"
    );
    let mut ok = true;
    for row in &rows {
        let ratio = row.ratio();
        let in_budget = ratio.is_finite() && ratio <= budget && ratio >= 1.0 / budget;
        ok &= in_budget;
        println!(
            "{:<9} {:<9} {:>13.3e} {:>13.3e} {:>9.2}{}",
            row.workload,
            row.proto.name(),
            row.measured,
            row.predicted,
            ratio,
            if in_budget { "" } else { "  <-- outside budget" }
        );
    }
    let sum_ln = rows.iter().map(|r| r.ratio().abs().max(1e-300).ln()).sum::<f64>();
    let geomean = (sum_ln / rows.len() as f64).exp();
    println!("geomean measured/predicted ratio: {geomean:.2} (budget {budget:.0}x)");

    let mut arr = Vec::with_capacity(rows.len());
    for row in &rows {
        let mut o = Value::obj();
        o.set("workload", Value::Str(row.workload.into()));
        o.set("proto", Value::Str(row.proto.name().into()));
        o.set("measured_s", Value::Num(row.measured));
        o.set("predicted_s", Value::Num(row.predicted));
        o.set("ratio", Value::Num(row.ratio()));
        arr.push(o);
    }
    let mut root = Value::obj();
    root.set("bench", Value::Str("transport_validate".into()));
    root.set("procs", Value::Num(procs as f64));
    root.set("steps", Value::Num(steps as f64));
    root.set("socket_latency_s", Value::Num(probe.latency));
    root.set("socket_bandwidth_Bps", Value::Num(probe.bandwidth));
    root.set("budget", Value::Num(budget));
    root.set("geomean_ratio", Value::Num(geomean));
    root.set("rows", Value::Arr(arr));
    crate::benchlib::save_bench_json("BENCH_transport.json", "transport validation", &root);

    ensure!(
        ok && geomean.is_finite(),
        "transport validation failed: at least one measured/predicted ratio outside {budget:.0}x"
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_spec_json_roundtrip() {
        for name in WORKLOADS {
            let spec = WorkloadSpec::for_name(name, 3).unwrap();
            let back = WorkloadSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec.to_json().compact(), back.to_json().compact(), "{name}");
            assert_eq!(spec.plan().fingerprint(), back.plan().fingerprint(), "{name}");
            assert_eq!(back.procs(), 3);
        }
        assert!(WorkloadSpec::for_name("nope", 2).is_none());
    }

    #[test]
    fn chaos_json_roundtrip() {
        for c in [
            ChaosAction::None,
            ChaosAction::KillAt(4),
            ChaosAction::SlowAt(2, Duration::from_millis(1500)),
        ] {
            assert_eq!(ChaosAction::from_json(&c.to_json()).unwrap(), c);
        }
        assert!(ChaosAction::from_json(&Value::obj()).is_ok()); // defaults to None
    }

    #[test]
    fn chaos_fire_semantics() {
        assert!(ChaosAction::None.fire(1));
        assert!(ChaosAction::KillAt(3).fire(2));
        assert!(!ChaosAction::KillAt(3).fire(3));
        assert!(ChaosAction::SlowAt(2, Duration::ZERO).fire(2));
    }

    #[test]
    fn plan_mode_variants_compile() {
        for m in PlanMode::ALL {
            assert_eq!(PlanMode::parse(m.name()), Some(m));
        }
        assert_eq!(PlanMode::parse("opt"), Some(PlanMode::Optimized));
        assert_eq!(PlanMode::parse("bogus"), None);
        // SpMV: the analyzer's plan is already condensed, so optimizing it
        // is a no-op, the raw plan is strictly bigger, and optimizing the
        // raw plan converges back to the compiled one.
        let spec = WorkloadSpec::for_name("spmv", 3).unwrap();
        let compiled = spec.plan();
        let raw = spec.plan_with(PlanMode::Raw);
        let opt = spec.plan_with(PlanMode::Optimized);
        assert!(raw.total_values() > compiled.total_values());
        assert_eq!(opt.fingerprint(), compiled.fingerprint());
        assert_eq!(
            PlanOptimizer::default().optimize(&raw).fingerprint(),
            compiled.fingerprint()
        );
        // Strided workloads: all three variants carry the same payload per
        // step; the raw one pays one message per cell.
        for name in ["heat", "stencil"] {
            let spec = WorkloadSpec::for_name(name, 2).unwrap();
            let compiled = spec.plan();
            let raw = spec.plan_with(PlanMode::Raw);
            let opt = spec.plan_with(PlanMode::Optimized);
            assert_eq!(raw.payload_bytes(), compiled.payload_bytes(), "{name}");
            assert_eq!(opt.payload_bytes(), compiled.payload_bytes(), "{name}");
            assert_eq!(raw.num_messages(), compiled.total_values(), "{name}");
            assert!(opt.num_messages() <= compiled.num_messages(), "{name}");
        }
    }

    #[test]
    fn proto_names_roundtrip() {
        for p in Proto::ALL {
            assert_eq!(Proto::parse(p.name()), Some(p));
        }
        assert_eq!(Proto::parse("overlapped"), Some(Proto::Overlap));
        assert_eq!(Proto::parse("bogus"), None);
    }

    #[test]
    fn reference_counters_match_plan() {
        let spec = WorkloadSpec::for_name("heat", 2).unwrap();
        let steps = 3u64;
        let out = run_reference(&spec, Proto::Sync, steps);
        assert_eq!(out.transfers, steps * spec.plan().num_messages() as u64);
        assert!(out.bytes > 0);
        assert_eq!(out.fields.len(), 2);
        assert!(out.stalls.is_empty() && out.killed.is_empty());
    }
}
