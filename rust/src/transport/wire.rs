//! Socket wire format: length-framed pack buffers with epoch headers.
//!
//! The epoch-flags protocol maps onto frames one-to-one:
//!
//! * `EpochFlags::publish(rank, e)` → one [`KIND_DATA`] frame per outgoing
//!   plan message, each carrying `e` in its header plus the packed payload
//!   (the arena slots the in-process backend would have written). A
//!   receiver's "flag reached `e`" is "every expected `DATA` frame of epoch
//!   `e` arrived from that sender".
//! * consumed-epoch `ack.publish(rank, e)` → one empty [`KIND_ACK`] frame
//!   per sending peer, carrying `e`; the peer's ack counter is the max ack
//!   epoch received.
//! * [`KIND_HELLO`] identifies the connecting rank during mesh setup and
//!   never appears after it.
//!
//! Data/ack frames share one fixed header — kind, sender rank, epoch, arena
//! start slot, payload count — followed by `count` little-endian `f64`s.
//! Control-plane messages (plan shipping, results) use a separate
//! `u32`-length-prefixed byte framing ([`write_msg`]/[`read_msg`]), JSON or
//! raw `f64` bytes at the call sites.

use std::io::{self, Read, Write};

/// Mesh handshake: "I am rank `sender`". No payload.
pub const KIND_HELLO: u8 = 1;
/// One packed plan message of an epoch.
pub const KIND_DATA: u8 = 2;
/// Consumed-epoch acknowledgement. No payload.
pub const KIND_ACK: u8 = 3;
/// A shipped [`PlanDelta`](crate::comm::PlanDelta): the incremental plan
/// lifecycle's wire frame. The header is reinterpreted — `epoch` carries
/// the **target plan generation** and `start` the **true byte length** of
/// the JSON body, whose bytes ride in the payload padded to whole doubles
/// ([`delta_payload`]/[`delta_bytes`]). Reusing the data framing keeps the
/// reader threads single-format: a delta parks in the mailbox like any
/// other frame and is drained at the rebuild boundary.
pub const KIND_DELTA: u8 = 4;

/// Frame header bytes: kind (1) + sender (4) + epoch (8) + start (4) +
/// count (4).
pub const HEADER_LEN: usize = 21;

/// Sanity cap on a frame's payload (2²⁴ doubles = 128 MiB): anything larger
/// is a corrupt or hostile header, rejected as `InvalidData` rather than
/// allocated.
pub const MAX_FRAME_VALUES: usize = 1 << 24;

/// Cap on a control-plane message (plans, fields, results).
pub const MAX_MSG_BYTES: usize = 1 << 28;

/// One decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: u8,
    /// The sending rank.
    pub sender: u32,
    /// The epoch counter carried in the header.
    pub epoch: u64,
    /// First arena slot of the payload (global plan coordinates).
    pub start: u32,
    pub payload: Vec<f64>,
}

/// Encode and send one frame as a single `write_all` (header + payload
/// assembled into one buffer, so a frame is never interleaved mid-write).
pub fn write_frame(
    w: &mut impl Write,
    kind: u8,
    sender: u32,
    epoch: u64,
    start: u32,
    payload: &[f64],
) -> io::Result<()> {
    assert!(payload.len() <= MAX_FRAME_VALUES, "frame payload over the wire cap");
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len() * 8);
    buf.push(kind);
    buf.extend_from_slice(&sender.to_le_bytes());
    buf.extend_from_slice(&epoch.to_le_bytes());
    buf.extend_from_slice(&start.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    for &v in payload {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&buf)
}

/// Read and decode one frame (blocking `read_exact`s). Oversized counts are
/// rejected with `InvalidData` before any payload allocation.
pub fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    let kind = header[0];
    let sender = u32::from_le_bytes(header[1..5].try_into().unwrap());
    let epoch = u64::from_le_bytes(header[5..13].try_into().unwrap());
    let start = u32::from_le_bytes(header[13..17].try_into().unwrap());
    let count = u32::from_le_bytes(header[17..21].try_into().unwrap()) as usize;
    if count > MAX_FRAME_VALUES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame claims {count} values (cap {MAX_FRAME_VALUES})"),
        ));
    }
    let mut bytes = vec![0u8; count * 8];
    r.read_exact(&mut bytes)?;
    let payload = bytes_to_f64s(&bytes);
    Ok(Frame { kind, sender, epoch, start, payload })
}

/// Send one `u32`-length-prefixed control message.
pub fn write_msg(w: &mut impl Write, bytes: &[u8]) -> io::Result<()> {
    assert!(bytes.len() <= MAX_MSG_BYTES, "control message over the wire cap");
    let mut buf = Vec::with_capacity(4 + bytes.len());
    buf.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(bytes);
    w.write_all(&buf)
}

/// Read one `u32`-length-prefixed control message.
pub fn read_msg(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_MSG_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("control message claims {len} bytes (cap {MAX_MSG_BYTES})"),
        ));
    }
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    Ok(bytes)
}

/// Flatten doubles to little-endian bytes (bulk field shipping).
pub fn f64s_to_bytes(vals: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 8);
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Inverse of [`f64s_to_bytes`]; ignores a trailing partial chunk (none is
/// ever produced by the writer).
pub fn bytes_to_f64s(bytes: &[u8]) -> Vec<f64> {
    bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect()
}

/// Pack an arbitrary byte body (a delta's JSON) into a [`KIND_DELTA`]
/// frame's `(start, payload)` pair: the bytes zero-padded to whole doubles,
/// plus the true length to travel in the header's `start` field.
pub fn delta_payload(bytes: &[u8]) -> (u32, Vec<f64>) {
    assert!(bytes.len() <= u32::MAX as usize, "delta body over the wire cap");
    let mut padded = bytes.to_vec();
    while padded.len() % 8 != 0 {
        padded.push(0);
    }
    (bytes.len() as u32, bytes_to_f64s(&padded))
}

/// Inverse of [`delta_payload`]: recover the byte body from a decoded
/// [`KIND_DELTA`] frame's payload and true length. A length that exceeds
/// the payload is a corrupt header.
pub fn delta_bytes(true_len: u32, payload: &[f64]) -> Result<Vec<u8>, String> {
    let mut bytes = f64s_to_bytes(payload);
    if true_len as usize > bytes.len() {
        return Err(format!(
            "delta frame claims {true_len} bytes but carries only {}",
            bytes.len()
        ));
    }
    bytes.truncate(true_len as usize);
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip() {
        let payload = vec![1.5, -2.25, 3.0e-9, f64::MAX];
        let mut buf = Vec::new();
        write_frame(&mut buf, KIND_DATA, 3, 17, 40, &payload).unwrap();
        assert_eq!(buf.len(), HEADER_LEN + payload.len() * 8);
        let f = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(f, Frame { kind: KIND_DATA, sender: 3, epoch: 17, start: 40, payload });
    }

    #[test]
    fn empty_ack_frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, KIND_ACK, 0, 9, 0, &[]).unwrap();
        assert_eq!(buf.len(), HEADER_LEN);
        let f = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(f.kind, KIND_ACK);
        assert_eq!(f.epoch, 9);
        assert!(f.payload.is_empty());
    }

    #[test]
    fn oversized_count_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        write_frame(&mut buf, KIND_DATA, 1, 1, 0, &[4.0]).unwrap();
        // Corrupt the count field to a huge value.
        buf[17..21].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn truncated_frame_is_an_io_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, KIND_DATA, 1, 1, 0, &[4.0, 5.0]).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_frame(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn control_msg_roundtrip() {
        let mut buf = Vec::new();
        write_msg(&mut buf, b"{\"rank\":2}").unwrap();
        write_msg(&mut buf, b"").unwrap();
        let mut c = Cursor::new(&buf);
        assert_eq!(read_msg(&mut c).unwrap(), b"{\"rank\":2}");
        assert_eq!(read_msg(&mut c).unwrap(), b"");
    }

    #[test]
    fn oversized_control_msg_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_msg(&mut Cursor::new(&buf)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn f64_bytes_roundtrip() {
        let vals = vec![0.0, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE];
        assert_eq!(bytes_to_f64s(&f64s_to_bytes(&vals)), vals);
    }

    #[test]
    fn delta_payload_roundtrip_through_a_frame() {
        // Lengths that hit several padding residues, including 0 and ×8.
        for len in [0usize, 1, 7, 8, 9, 24, 31] {
            let body: Vec<u8> = (0..len as u8).map(|b| b.wrapping_mul(37)).collect();
            let (true_len, payload) = delta_payload(&body);
            assert_eq!(true_len as usize, len);
            assert_eq!(payload.len(), len.div_ceil(8));
            let mut buf = Vec::new();
            write_frame(&mut buf, KIND_DELTA, 0, 3, true_len, &payload).unwrap();
            let f = read_frame(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(f.kind, KIND_DELTA);
            assert_eq!(f.epoch, 3, "generation travels in the epoch field");
            assert_eq!(delta_bytes(f.start, &f.payload).unwrap(), body);
        }
    }

    #[test]
    fn delta_bytes_rejects_overlong_claim() {
        let (_, payload) = delta_payload(b"abc");
        let err = delta_bytes(100, &payload).unwrap_err();
        assert!(err.contains("claims 100 bytes"), "{err}");
    }
}
