//! Transport-generic replay of the strided exchange protocols.
//!
//! [`ProcRuntime`] is the per-rank analogue of the engine's in-process
//! `ExchangeRuntime`: it drives the same sync / overlapped / pipelined
//! epoch schedules, but through the [`Transport`] trait, so one body runs
//! unchanged over shared memory or sockets. Differences from the pool
//! engine, both deliberate:
//!
//! * no barriers — the protocols are data-synchronized (epoch waits + acks
//!   order every cross-rank access), and a barrier has no socket analogue;
//!   results are bitwise identical either way.
//! * the runtime never swaps the caller's buffers in sync/overlapped mode
//!   (the caller owns that), while [`run_pipelined`](ProcRuntime::run_pipelined)
//!   swaps per epoch so the final iterate lands back in `field` — matching
//!   the engine's pipelined contract.

use super::Transport;
use crate::comm::ExchangePlan;
use crate::engine::StallError;

/// One rank's protocol driver: a compiled strided plan plus a transport
/// endpoint and the rank's monotone epoch counter.
pub struct ProcRuntime<T: Transport> {
    plan: ExchangePlan,
    transport: T,
    epoch: u64,
    /// Pipeline depth D of the ack gate: a sender may run at most D epochs
    /// ahead of its slowest receiver. Must match the transport's staging
    /// depth (e.g. `SocketTransport::with_depth`); defaults to 2.
    depth: u64,
    /// Distinct peers this rank receives halo data from.
    senders: Vec<usize>,
    /// Distinct peers this rank sends halo data to (ack-gate targets).
    receivers: Vec<usize>,
}

impl<T: Transport> ProcRuntime<T> {
    /// Bind `transport` (already wired for `transport.rank()`) to `plan`.
    /// Only strided plans drive this runtime — the gather-form SpMV path
    /// has its own rank driver in [`super::launch`].
    pub fn new(plan: ExchangePlan, transport: T) -> ProcRuntime<T> {
        let rank = transport.rank();
        let strided = plan.as_strided().expect("ProcRuntime drives strided plans");
        let mut senders: Vec<usize> = strided.recv_msgs(rank).map(|m| m.peer as usize).collect();
        senders.sort_unstable();
        senders.dedup();
        let mut receivers: Vec<usize> = strided.send_msgs(rank).map(|m| m.peer as usize).collect();
        receivers.sort_unstable();
        receivers.dedup();
        ProcRuntime { plan, transport, epoch: 0, depth: 2, senders, receivers }
    }

    /// The transport endpoint (e.g. to read wire counters before drop).
    pub fn transport(&self) -> &T {
        &self.transport
    }

    /// Epochs completed so far.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The pipeline depth of the [`run_pipelined`](ProcRuntime::run_pipelined)
    /// ack gate.
    pub fn depth(&self) -> u64 {
        self.depth
    }

    /// Set the pipeline depth. The transport's staging arena must hold at
    /// least `depth` slots (construct it with the same depth); call only at
    /// batch boundaries — epochs stay monotone across the change.
    pub fn set_depth(&mut self, depth: u64) {
        assert!(depth >= 1, "pipeline depth must be at least 1");
        self.depth = depth;
    }

    /// One synchronous step: pack → publish → wait all senders → unpack →
    /// ack → `update(field, out)`. The caller swaps `field`/`out` after.
    pub fn step_strided(
        &mut self,
        field: &mut [f64],
        out: &mut [f64],
        update: impl FnOnce(&[f64], &mut [f64]),
    ) -> Result<(), StallError> {
        let ProcRuntime { plan, transport, epoch, senders, .. } = self;
        let rank = transport.rank();
        let strided = plan.as_strided().expect("strided plan");
        *epoch += 1;
        let e = *epoch;
        for m in strided.send_msgs(rank) {
            m.pack(field, transport.send_slot(e, m.range()));
        }
        transport.publish(e)?;
        for &peer in senders.iter() {
            transport.wait_for_epoch(peer, e)?;
        }
        for m in strided.recv_msgs(rank) {
            m.unpack(transport.recv_slot(e, m.range()), field);
        }
        transport.ack(e)?;
        update(field, out);
        Ok(())
    }

    /// One split-phase step: pack → publish → `interior(field, out)` while
    /// the halo is in flight → wait/unpack → ack → `boundary(field, out)`.
    pub fn step_overlapped(
        &mut self,
        field: &mut [f64],
        out: &mut [f64],
        interior: impl FnOnce(&[f64], &mut [f64]),
        boundary: impl FnOnce(&[f64], &mut [f64]),
    ) -> Result<(), StallError> {
        let ProcRuntime { plan, transport, epoch, senders, .. } = self;
        let rank = transport.rank();
        let strided = plan.as_strided().expect("strided plan");
        *epoch += 1;
        let e = *epoch;
        for m in strided.send_msgs(rank) {
            m.pack(field, transport.send_slot(e, m.range()));
        }
        transport.publish(e)?;
        interior(field, out);
        for &peer in senders.iter() {
            transport.wait_for_epoch(peer, e)?;
        }
        for m in strided.recv_msgs(rank) {
            m.unpack(transport.recv_slot(e, m.range()), field);
        }
        transport.ack(e)?;
        boundary(field, out);
        Ok(())
    }

    /// `steps` pipelined epochs with the depth-D consumed-epoch ack gate
    /// (epoch `e` may not publish before every receiver acked `e − D`,
    /// where D is [`depth`](ProcRuntime::depth)). Swaps `field`/`out` each
    /// epoch; the final iterate ends in `field`. `on_epoch(e)` fires before
    /// each epoch's gate — the chaos hook.
    pub fn run_pipelined(
        &mut self,
        steps: u64,
        field: &mut Vec<f64>,
        out: &mut Vec<f64>,
        mut interior: impl FnMut(&[f64], &mut [f64]),
        mut boundary: impl FnMut(&[f64], &mut [f64]),
        mut on_epoch: impl FnMut(u64),
    ) -> Result<(), StallError> {
        let base = self.epoch;
        self.epoch += steps;
        for k in 1..=steps {
            let e = base + k;
            on_epoch(e);
            let ProcRuntime { plan, transport, depth, senders, receivers, .. } = &mut *self;
            let depth = *depth;
            let rank = transport.rank();
            let strided = plan.as_strided().expect("strided plan");
            if k > depth {
                for &peer in receivers.iter() {
                    transport.wait_for_ack(peer, e - depth)?;
                }
            }
            for m in strided.send_msgs(rank) {
                m.pack(field, transport.send_slot(e, m.range()));
            }
            transport.publish(e)?;
            interior(field, out);
            for &peer in senders.iter() {
                transport.wait_for_epoch(peer, e)?;
            }
            for m in strided.recv_msgs(rank) {
                m.unpack(transport.recv_slot(e, m.range()), field);
            }
            transport.ack(e)?;
            boundary(field, out);
            std::mem::swap(field, out);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{StridedBlock, StridedPlan};
    use crate::transport::{loopback_mesh, SocketTransport};
    use std::time::Duration;

    /// 1-D two-rank halo: each rank owns slots 1..=2 of a 4-wide field with
    /// ghost slots 0 and 3; ranks exchange their edge cells.
    fn line_plan() -> ExchangePlan {
        StridedPlan::from_msgs(
            2,
            &[
                // rank 0's right edge (slot 2) → rank 1's left ghost (slot 0)
                (0, 1, StridedBlock::row(2, 1), StridedBlock::row(0, 1)),
                // rank 1's left edge (slot 1) → rank 0's right ghost (slot 3)
                (1, 0, StridedBlock::row(1, 1), StridedBlock::row(3, 1)),
            ],
        )
        .into()
    }

    fn run_world<F>(steps: u64, drive: F) -> Vec<Vec<f64>>
    where
        F: Fn(usize, &mut ProcRuntime<SocketTransport>, &mut Vec<f64>, &mut Vec<f64>, u64) + Sync,
    {
        let plan = line_plan();
        let mesh = loopback_mesh(2).unwrap();
        std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, row)| {
                    let plan = plan.clone();
                    let drive = &drive;
                    s.spawn(move || {
                        let deadline = Some(Duration::from_secs(10));
                        let t = SocketTransport::new(rank, &plan, row, deadline).unwrap();
                        let mut rt = ProcRuntime::new(plan, t);
                        // Interior cells start at rank-distinct values.
                        let mut field = vec![0.0; 4];
                        field[1] = (rank * 10 + 1) as f64;
                        field[2] = (rank * 10 + 2) as f64;
                        let mut out = vec![0.0; 4];
                        drive(rank, &mut rt, &mut field, &mut out, steps);
                        field
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    /// 3-point average of the interior, ghosts held.
    fn relax(src: &[f64], dst: &mut [f64]) {
        dst[0] = src[0];
        dst[3] = src[3];
        for i in 1..=2 {
            dst[i] = (src[i - 1] + src[i] + src[i + 1]) / 3.0;
        }
    }

    #[test]
    fn sync_overlapped_and_pipelined_agree() {
        let steps = 4u64;
        let sync = run_world(steps, |_r, rt, field, out, steps| {
            for _ in 0..steps {
                rt.step_strided(field, out, relax).unwrap();
                std::mem::swap(field, out);
            }
        });
        let over = run_world(steps, |_r, rt, field, out, steps| {
            for _ in 0..steps {
                rt.step_overlapped(
                    field,
                    out,
                    |src, dst| {
                        // "Interior" = nothing halo-dependent; full update
                        // waits for the boundary phase.
                        dst[0] = src[0];
                    },
                    |src, dst| {
                        dst[3] = src[3];
                        for i in 1..=2 {
                            dst[i] = (src[i - 1] + src[i] + src[i + 1]) / 3.0;
                        }
                    },
                )
                .unwrap();
                std::mem::swap(field, out);
            }
        });
        let piped = run_world(steps, |_r, rt, field, out, steps| {
            rt.run_pipelined(
                steps,
                field,
                out,
                |src, dst| dst[0] = src[0],
                |src, dst| {
                    dst[3] = src[3];
                    for i in 1..=2 {
                        dst[i] = (src[i - 1] + src[i] + src[i + 1]) / 3.0;
                    }
                },
                |_e| {},
            )
            .unwrap();
        });
        assert_eq!(sync, over, "overlapped diverged from sync");
        assert_eq!(sync, piped, "pipelined diverged from sync");
        // Halo actually moved: rank 0's right ghost carries rank 1 data.
        assert_ne!(sync[0][3], 0.0);
    }

    #[test]
    fn pipelined_depths_agree_with_sync() {
        // D ∈ {1, 3, 4} over the socket transport, each vs the synchronous
        // schedule: the depth only changes buffering/lead, never values.
        let steps = 5u64;
        let sync = run_world(steps, |_r, rt, field, out, steps| {
            for _ in 0..steps {
                rt.step_strided(field, out, relax).unwrap();
                std::mem::swap(field, out);
            }
        });
        for depth in [1u64, 3, 4] {
            let plan = line_plan();
            let mesh = loopback_mesh(2).unwrap();
            let piped: Vec<Vec<f64>> = std::thread::scope(|s| {
                let handles: Vec<_> = mesh
                    .into_iter()
                    .enumerate()
                    .map(|(rank, row)| {
                        let plan = plan.clone();
                        s.spawn(move || {
                            let deadline = Some(Duration::from_secs(10));
                            let t = SocketTransport::with_depth(
                                rank,
                                &plan,
                                row,
                                deadline,
                                depth as usize,
                            )
                            .unwrap();
                            let mut rt = ProcRuntime::new(plan, t);
                            rt.set_depth(depth);
                            assert_eq!(rt.depth(), depth);
                            let mut field = vec![0.0; 4];
                            field[1] = (rank * 10 + 1) as f64;
                            field[2] = (rank * 10 + 2) as f64;
                            let mut out = vec![0.0; 4];
                            rt.run_pipelined(
                                steps,
                                &mut field,
                                &mut out,
                                |src, dst| dst[0] = src[0],
                                |src, dst| {
                                    dst[3] = src[3];
                                    for i in 1..=2 {
                                        dst[i] = (src[i - 1] + src[i] + src[i + 1]) / 3.0;
                                    }
                                },
                                |_e| {},
                            )
                            .unwrap();
                            field
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            assert_eq!(sync, piped, "depth {depth} diverged from sync");
        }
    }

    #[test]
    fn pipelined_epoch_hook_sees_every_epoch() {
        let plan = line_plan();
        let mesh = loopback_mesh(2).unwrap();
        let epochs: Vec<Vec<u64>> = std::thread::scope(|s| {
            let handles: Vec<_> = mesh
                .into_iter()
                .enumerate()
                .map(|(rank, row)| {
                    let plan = plan.clone();
                    s.spawn(move || {
                        let deadline = Some(Duration::from_secs(10));
                        let t = SocketTransport::new(rank, &plan, row, deadline).unwrap();
                        let mut rt = ProcRuntime::new(plan, t);
                        let mut field = vec![1.0; 4];
                        let mut out = vec![0.0; 4];
                        let mut seen = Vec::new();
                        rt.run_pipelined(
                            3,
                            &mut field,
                            &mut out,
                            |_s, _d| {},
                            |src, dst| dst.copy_from_slice(src),
                            |e| seen.push(e),
                        )
                        .unwrap();
                        assert_eq!(rt.epoch(), 3);
                        seen
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(epochs, vec![vec![1, 2, 3], vec![1, 2, 3]]);
    }
}
