//! Pluggable transports: one compiled [`ExchangePlan`], many memory worlds.
//!
//! Every exchange protocol in this repo (sync, split-phase overlapped,
//! multi-step pipelined) reduces to five operations against a depth-D
//! staging arena (D buffered slots, indexed by `epoch mod D`; D = 2 is the
//! classic double-buffer): obtain a send/recv view of an epoch's arena
//! slot, publish an epoch, wait for a peer's epoch, acknowledge a consumed
//! epoch, and wait for a peer's ack. [`Transport`] names exactly those
//! operations, so the protocol drivers stop caring *where* the peer's
//! memory lives:
//!
//! * [`PoolEndpoint`] — the original in-process backend: `EpochFlags`
//!   (padded release/acquire counters) plus a shared `ArenaView`, bitwise
//!   identical to the pre-trait engine hot path.
//! * [`SocketTransport`] — a genuinely distributed backend: each rank owns
//!   a private arena copy and length-framed `TcpStream` messages carry the
//!   pack buffers, with epoch counters in the frame headers standing in for
//!   the epoch flags (see [`wire`] docs for the mapping).
//!
//! [`ProcRuntime`] replays the strided protocols over any `Transport`;
//! [`launch`] orchestrates whole multi-process worlds (`repro launch`).
//!
//! [`ExchangePlan`]: crate::comm::ExchangePlan

mod inproc;
mod launch;
mod proc_runtime;
mod socket;
mod wire;

pub use inproc::PoolEndpoint;
pub use launch::{
    auto_depth, cmd_launch, run_reference, run_reference_mode, run_socket_world,
    run_socket_world_depth, run_socket_world_mode, validate_transport, worker_main, ChaosAction,
    LaunchConfig, PlanMode, Proto, SpmvParams, TransportRow, WorkloadSpec, WorldOutcome,
    CHAOS_EXIT_CODE, WORKLOADS,
};
pub use proc_runtime::ProcRuntime;
pub use socket::{loopback_mesh, socket_probe, MeshStreams, SocketProbe, SocketTransport};

use crate::engine::{Phase, StallError, WaitTuning};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// The five operations the exchange protocols need from a memory world.
///
/// One endpoint instance belongs to one rank (logical UPC thread). Epochs
/// are the monotone `u64` counters of the in-process protocol: `publish`
/// and `ack` must be called with nondecreasing epochs, and
/// `wait_for_epoch`/`wait_for_ack` must be idempotent per `(peer, epoch)` —
/// waiting again for an epoch already drained returns `Ok` immediately.
///
/// Every wait is deadline-aware: a peer that never arrives converts into a
/// structured [`StallError`] naming the waiter, the absent peer (with its
/// transport identity), the epoch and the protocol phase — never a hang.
pub trait Transport {
    /// This endpoint's rank in `0..threads` of the compiled plan.
    fn rank(&self) -> usize;

    /// Short backend name (`"inproc"`, `"socket"`).
    fn kind(&self) -> &'static str;

    /// Human-readable identity of a peer endpoint, for [`StallError`]
    /// messages (e.g. `inproc:worker-3`, `socket:rank-1@127.0.0.1:4710`).
    fn peer_identity(&self, peer: usize) -> String;

    /// Publish `epoch`: every outgoing message of the epoch is packed into
    /// this rank's send slots and may now be observed by its receivers.
    fn publish(&mut self, epoch: u64) -> Result<(), StallError>;

    /// Wait until `peer`'s published epoch reaches `epoch` — after which
    /// every value `peer` sent this rank for the epoch is readable through
    /// [`recv_slot`](Transport::recv_slot).
    fn wait_for_epoch(&mut self, peer: usize, epoch: u64) -> Result<(), StallError>;

    /// Acknowledge `epoch` as consumed: this rank has unpacked every
    /// incoming message of the epoch, so its senders may reuse the arena
    /// slot (depth-D pipeline back-pressure).
    fn ack(&mut self, epoch: u64) -> Result<(), StallError>;

    /// Wait until `peer`'s consumed-epoch ack reaches `epoch`.
    fn wait_for_ack(&mut self, peer: usize, epoch: u64) -> Result<(), StallError>;

    /// Mutable staging view of `range` (global arena coordinates, as handed
    /// out by the plan's `msg.range()`) in `epoch`'s parity half — the pack
    /// target of one outgoing message.
    fn send_slot(&mut self, epoch: u64, range: Range<usize>) -> &mut [f64];

    /// Shared staging view of `range` in `epoch`'s parity half — the unpack
    /// source of one incoming message. Only valid after
    /// [`wait_for_epoch`](Transport::wait_for_epoch) on the sending peer.
    fn recv_slot(&mut self, epoch: u64, range: Range<usize>) -> &[f64];

    /// Payload bytes this endpoint has put on the wire (0 where the backend
    /// does not meter, e.g. in-process shared memory).
    fn sent_payload_bytes(&self) -> u64 {
        0
    }

    /// Data transfers (messages) this endpoint has put on the wire (0 where
    /// the backend does not meter).
    fn sent_transfers(&self) -> u64 {
        0
    }
}

/// Unwrap a transport result inside pool-worker code: a [`StallError`]
/// re-enters the engine's poison-and-unwind path via `panic_any`, exactly
/// as the pre-trait wait primitives raised it, so dispatchers keep
/// recovering it with [`StallError::from_panic`].
pub fn must<T>(r: Result<T, StallError>) -> T {
    match r {
        Ok(v) => v,
        Err(e) => std::panic::panic_any(e),
    }
}

/// Pool-free deadline-aware epoch-flag wait: the spin → yield → timed-park
/// ladder of `WorkerCtx::wait_for_epoch`, usable outside a `WorkerPool`
/// dispatch (e.g. the scoped-thread MPI baseline). Rung sizes come from
/// the caller's [`WaitTuning`] (pass `WaitTuning::default()` for the
/// historical constants). Returns a structured [`StallError`] instead of
/// panicking, and does not consult any poison flag — the caller owns
/// failure propagation.
#[allow(clippy::too_many_arguments)]
pub fn wait_epoch_flag(
    flag: &AtomicU64,
    target: u64,
    deadline: Option<Duration>,
    tuning: WaitTuning,
    waiter: usize,
    peer: usize,
    phase: Phase,
    identity: &str,
) -> Result<(), StallError> {
    for _ in 0..tuning.spin {
        if flag.load(Ordering::Acquire) >= target {
            return Ok(());
        }
        std::hint::spin_loop();
    }
    let start = Instant::now();
    let mut rounds = 0u32;
    loop {
        if flag.load(Ordering::Acquire) >= target {
            return Ok(());
        }
        if let Some(d) = deadline {
            let waited = start.elapsed();
            if waited >= d {
                return Err(StallError {
                    waiter,
                    peer: Some(peer),
                    epoch: target,
                    phase,
                    waited,
                    transport: Some(identity.to_string()),
                });
            }
        }
        rounds += 1;
        if rounds < tuning.yield_rounds {
            std::thread::yield_now();
        } else {
            std::thread::park_timeout(tuning.park);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn wait_epoch_flag_returns_on_published_flag() {
        let flag = AtomicU64::new(3);
        wait_epoch_flag(
            &flag,
            3,
            None,
            WaitTuning::default(),
            0,
            1,
            Phase::Transfer,
            "test:peer-1",
        )
        .unwrap();
    }

    #[test]
    fn wait_epoch_flag_times_out_with_identity() {
        let flag = AtomicU64::new(0);
        let err = wait_epoch_flag(
            &flag,
            5,
            Some(Duration::from_millis(20)),
            WaitTuning::default(),
            2,
            7,
            Phase::AckGate,
            "socket:rank-7@10.0.0.1:9",
        )
        .unwrap_err();
        assert_eq!(err.waiter, 2);
        assert_eq!(err.peer, Some(7));
        assert_eq!(err.epoch, 5);
        assert_eq!(err.phase, Phase::AckGate);
        let msg = err.to_string();
        assert!(msg.contains("socket:rank-7@10.0.0.1:9"), "{msg}");
    }

    #[test]
    fn wait_epoch_flag_sees_concurrent_publish() {
        let flag = AtomicU64::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(5));
                flag.store(9, Ordering::Release);
            });
            wait_epoch_flag(
                &flag,
                9,
                Some(Duration::from_secs(5)),
                WaitTuning::default(),
                0,
                1,
                Phase::Transfer,
                "inproc:worker-1",
            )
            .unwrap();
        });
    }

    #[test]
    fn must_passes_ok_through() {
        assert_eq!(must(Ok::<u32, StallError>(17)), 17);
    }
}
