//! Figures 1 and 2 of the paper (emitted as per-thread CSV series).

use super::{HarnessConfig, Workspace};
use crate::comm::Analysis;
use crate::mesh::{Ordering, TestProblem};
use crate::model::{self, SpmvInputs};
use crate::pgas::{Layout, Topology};
use crate::sim::ClusterSim;
use crate::spmv::Variant;
use crate::util::fmt::Table;
use crate::util::plot;

/// Render a figure table as an ASCII grouped-bar chart (one bar per column
/// beyond the first, grouped by row label) — saved as `<name>.plot.txt`.
pub fn plot_figure(table: &Table, max_rows: usize) -> String {
    let columns: Vec<&str> = table.headers[1..].iter().map(|s| s.as_str()).collect();
    let rows: Vec<(String, Vec<f64>)> = table
        .rows
        .iter()
        .take(max_rows)
        .map(|r| {
            (
                format!("thread {}", r[0]),
                r[1..].iter().map(|c| c.parse().unwrap_or(0.0)).collect(),
            )
        })
        .collect();
    plot::grouped_bars(&table.title, &columns, &rows, 48)
}

/// Figure 1: per-thread T_comp / T_unpack / T_pack for UPCv3, predicted vs
/// measured; 32 threads over 2 nodes, BLOCKSIZE = 65536 (scaled).
pub fn figure1(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let m = ws.matrix(TestProblem::Tp1, cfg.scale_div, Ordering::Natural);
    let bs = (65_536 / cfg.scale_div).max(1).min(m.n);
    let threads = 32;
    let layout = Layout::new(m.n, bs, threads);
    let topo = Topology::new(2, 16);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
    let hw = cfg.hw_for_tpn(16);
    let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
    let sim = ClusterSim::new(hw);
    let meas = sim.spmv_iteration(Variant::V3, &inp);
    let pred = model::predict_v3(&inp);

    let mut t = Table::new(
        format!(
            "Figure 1 — per-thread UPCv3 components, TP1, 32 threads / 2 nodes, BS={bs} (seconds per iteration)"
        ),
        &[
            "thread", "comp measured", "comp predicted", "unpack measured", "unpack predicted",
            "pack measured", "pack predicted",
        ],
    );
    let f = |x: f64| format!("{x:.6}");
    for th in 0..threads {
        t.row(vec![
            th.to_string(),
            f(meas.t_comp[th]),
            f(pred.t_comp[th]),
            f(meas.t_unpack[th]),
            f(pred.breakdown[th].t_unpack),
            f(meas.t_pack[th]),
            f(pred.breakdown[th].t_pack),
        ]);
    }
    t
}

/// Figure 2 (top): per-thread communication volumes for the three
/// transformed variants; 32 threads over 2 nodes, BLOCKSIZE = 65536 scaled.
pub fn figure2_volumes(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let m = ws.matrix(TestProblem::Tp1, cfg.scale_div, Ordering::Natural);
    let bs = (65_536 / cfg.scale_div).max(1).min(m.n);
    let threads = 32;
    let layout = Layout::new(m.n, bs, threads);
    let topo = Topology::new(2, 16);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
    let mut t = Table::new(
        format!("Figure 2 (top) — per-thread comm volume (MB), TP1, 32 threads, BS={bs}"),
        &["thread", "UPCv1", "UPCv2", "UPCv3"],
    );
    for th in 0..threads {
        let (v1, v2, v3) = analysis.volume_bytes(th);
        t.row(vec![
            th.to_string(),
            format!("{:.3}", v1 / 1e6),
            format!("{:.3}", v2 / 1e6),
            format!("{:.3}", v3 / 1e6),
        ]);
    }
    t
}

/// Figure 2 (bottom): UPCv3 per-thread volumes for a BLOCKSIZE sweep.
pub fn figure2_blocksize(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let m = ws.matrix(TestProblem::Tp1, cfg.scale_div, Ordering::Natural);
    let threads = 32;
    let paper_bs = [16_384usize, 32_768, 65_536, 131_072];
    let scaled: Vec<usize> =
        paper_bs.iter().map(|b| (b / cfg.scale_div).max(1).min(m.n)).collect();
    let headers: Vec<String> = std::iter::once("thread".to_string())
        .chain(scaled.iter().map(|b| format!("BS={b}")))
        .collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Figure 2 (bottom) — UPCv3 per-thread comm volume (MB) vs BLOCKSIZE, TP1, 32 threads",
        &headers_ref,
    );
    let mut columns: Vec<Vec<f64>> = Vec::new();
    for &bs in &scaled {
        let layout = Layout::new(m.n, bs, threads);
        let topo = Topology::new(2, 16);
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
        columns.push((0..threads).map(|th| analysis.volume_bytes(th).2).collect());
    }
    for th in 0..threads {
        let mut row = vec![th.to_string()];
        for col in &columns {
            row.push(format!("{:.3}", col[th] / 1e6));
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_has_32_threads() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = figure1(&cfg, &mut ws);
        assert_eq!(t.rows.len(), 32);
        // measured comp within 2x of predicted comp for thread 0
        let meas: f64 = t.rows[0][1].parse().unwrap();
        let pred: f64 = t.rows[0][2].parse().unwrap();
        assert!(meas > 0.0 && pred > 0.0);
        assert!((meas / pred) < 3.0 && (meas / pred) > 0.3, "{meas} vs {pred}");
    }

    #[test]
    fn plot_renders_figures() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = figure2_volumes(&cfg, &mut ws);
        let p = plot_figure(&t, 8);
        assert!(p.contains("thread 0"));
        assert!(p.contains("UPCv3"));
        assert!(p.contains("█"));
    }

    #[test]
    fn figure2_v3_never_exceeds_v2() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = figure2_volumes(&cfg, &mut ws);
        for row in &t.rows {
            let v2: f64 = row[2].parse().unwrap();
            let v3: f64 = row[3].parse().unwrap();
            assert!(v3 <= v2 + 1e-9, "thread {}: v3 {v3} > v2 {v2}", row[0]);
        }
    }

    #[test]
    fn figure2_blocksize_columns_monotone_threads() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = figure2_blocksize(&cfg, &mut ws);
        assert_eq!(t.rows.len(), 32);
        assert_eq!(t.headers.len(), 5);
    }
}
