//! Ablation studies beyond the paper's tables (DESIGN.md §3 "extensions"):
//! BLOCKSIZE tuning of the *total* time, row-ordering impact, and
//! threads-per-node sensitivity. These quantify the design choices the paper
//! discusses qualitatively (§6.4 "tuning BLOCKSIZE by the programmer is a
//! viable approach to performance optimization").

use super::{s2, HarnessConfig, Workspace};
use crate::comm::Analysis;
use crate::mesh::{Ordering, TestProblem};
use crate::model::SpmvInputs;
use crate::pgas::{Layout, Topology};
use crate::sim::ClusterSim;
use crate::spmv::Variant;
use crate::util::fmt::Table;

/// Total simulated time vs BLOCKSIZE for all three transformed variants
/// (TP1, 2 nodes × 16 threads).
pub fn ablation_blocksize(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let m = ws.matrix(TestProblem::Tp1, cfg.scale_div, Ordering::Natural);
    let paper_bs = [8_192usize, 16_384, 32_768, 65_536, 131_072, 262_144];
    let scaled: Vec<usize> = paper_bs
        .iter()
        .map(|b| (b / cfg.scale_div).max(1).min(m.n))
        .collect();
    let headers: Vec<String> = std::iter::once("variant".to_string())
        .chain(scaled.iter().map(|b| format!("BS={b}")))
        .collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("Ablation — total time vs BLOCKSIZE, TP1, 32 threads/2 nodes, {} iters", cfg.iters),
        &headers_ref,
    );
    let hw = cfg.hw_for_tpn(16);
    let sim = ClusterSim::new(hw);
    let topo = Topology::new(2, 16);
    for variant in Variant::TRANSFORMED {
        let mut row = vec![variant.name().to_string()];
        for &bs in &scaled {
            let layout = Layout::new(m.n, bs, 32);
            let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
            let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
            row.push(s2(sim.spmv_iteration(variant, &inp).total * cfg.iters as f64));
        }
        t.row(row);
    }
    t
}

/// Total simulated time per ordering (natural / RCM / Morton / random) —
/// quantifies how much the paper's "proper ordering" matters for both the
/// communication volume and the cache behaviour.
pub fn ablation_ordering(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let headers = ["ordering", "UPCv1", "UPCv3", "v3 comm MB", "mean |i-j|"];
    let mut t = Table::new(
        format!(
            "Ablation — row ordering, TP1, 32 threads/2 nodes, {} iters (simulated)",
            cfg.iters
        ),
        &headers,
    );
    let topo = Topology::new(2, 16);
    let hw = cfg.hw_for_tpn(16);
    let sim = ClusterSim::new(hw);
    for ordering in Ordering::ALL {
        let mesh = ws.mesh(TestProblem::Tp1, cfg.scale_div, ordering).clone();
        let m = ws.matrix(TestProblem::Tp1, cfg.scale_div, ordering);
        let bs = (65_536 / cfg.scale_div).max(1).min(m.n);
        let layout = Layout::new(m.n, bs, 32);
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
        let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
        let v1 = sim.spmv_iteration(Variant::V1, &inp).total * cfg.iters as f64;
        let v3 = sim.spmv_iteration(Variant::V3, &inp).total * cfg.iters as f64;
        let comm_mb: f64 =
            (0..32).map(|th| analysis.volume_bytes(th).2).sum::<f64>() / 1e6;
        t.row(vec![
            ordering.name().to_string(),
            s2(v1),
            s2(v3),
            format!("{comm_mb:.2}"),
            format!("{:.0}", mesh.mean_index_distance()),
        ]);
    }
    t
}

/// UPCv3 total vs threads-per-node at a fixed 32-thread budget — the
/// intra/inter-node traffic trade-off the paper's topology fixes at 16.
pub fn ablation_threads_per_node(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let m = ws.matrix(TestProblem::Tp1, cfg.scale_div, Ordering::Natural);
    let mut t = Table::new(
        format!(
            "Ablation — UPCv3 vs threads/node at 32 threads total, TP1, {} iters",
            cfg.iters
        ),
        &["threads/node", "nodes", "UPCv3 total", "remote msgs", "remote MB"],
    );
    for tpn in [2usize, 4, 8, 16, 32] {
        let nodes = 32 / tpn;
        let topo = Topology::new(nodes, tpn);
        let hw = cfg.hw_for_tpn(tpn);
        // The simulator reads its own copy of the parameters, so it must be
        // built per tpn too — one sim at 16 threads/node would price every
        // row's compute at the wrong bandwidth share.
        let sim = ClusterSim::new(hw);
        let bs = (65_536 / cfg.scale_div).max(1).min(m.n);
        let layout = Layout::new(m.n, bs, 32);
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
        let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
        let total = sim.spmv_iteration(Variant::V3, &inp).total * cfg.iters as f64;
        let msgs: u32 = analysis.per_thread.iter().map(|tt| tt.c_remote_out).sum();
        let mb: f64 =
            analysis.per_thread.iter().map(|tt| tt.s_remote_out as f64 * 8.0).sum::<f64>() / 1e6;
        t.row(vec![
            tpn.to_string(),
            nodes.to_string(),
            s2(total),
            msgs.to_string(),
            format!("{mb:.2}"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_ablation_random_is_worst() {
        let mut cfg = HarnessConfig::test_sized();
        cfg.iters = 5000; // enough that the 2-decimal cells resolve
        let mut ws = Workspace::new();
        let t = ablation_ordering(&cfg, &mut ws);
        assert_eq!(t.rows.len(), 4);
        let v3_of = |name: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0] == name)
                .map(|r| r[2].parse().unwrap())
                .unwrap()
        };
        assert!(v3_of("random") > v3_of("natural"), "random should be slowest");
    }

    #[test]
    fn blocksize_ablation_runs() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = ablation_blocksize(&cfg, &mut ws);
        assert_eq!(t.rows.len(), 3);
    }

    #[test]
    fn tpn_ablation_more_nodes_more_remote_traffic() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = ablation_threads_per_node(&cfg, &mut ws);
        let first_mb: f64 = t.rows.first().unwrap()[4].parse().unwrap();
        let last_mb: f64 = t.rows.last().unwrap()[4].parse().unwrap();
        // 2 threads/node (16 nodes) has far more inter-node traffic than
        // 32 threads on one node (zero).
        assert!(first_mb > last_mb);
        assert_eq!(last_mb, 0.0);
    }
}
