//! Baseline comparison (paper §9): UPCv3 vs the MPI-style two-sided
//! contiguous-partition implementation.
//!
//! Quantifies the paper's concluding claims: MPI's flexible (contiguous)
//! partitioning and local-index ghost regions buy better locality (no
//! scattered unpack, no own-copy pass), at the programmability cost of the
//! global→local relabeling step.

use super::{s2, HarnessConfig, Workspace};
use crate::comm::Analysis;
use crate::mesh::{Ordering, TestProblem};
use crate::model::SpmvInputs;
use crate::pgas::{Layout, Topology};
use crate::sim::{ClusterSim, SimParams};
use crate::spmv::{MpiSolver, Variant};
use crate::util::fmt::Table;

/// UPCv3 vs MPI-style across node counts (TP1).
pub fn baseline_mpi(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let m = ws.matrix(TestProblem::Tp1, cfg.scale_div, Ordering::Natural);
    let x0 = m.initial_vector(1);
    let nodes_list = [1usize, 2, 4, 8, 16];
    let headers: Vec<String> = std::iter::once("implementation".to_string())
        .chain(nodes_list.iter().map(|n| format!("{n} node{}", if *n > 1 { "s" } else { "" })))
        .collect();
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!(
            "§9 baseline — UPCv3 vs MPI-style two-sided, TP1, {} iters (simulated)",
            cfg.iters
        ),
        &headers_ref,
    );
    let hw = cfg.hw_for_tpn(16);
    let sim = ClusterSim::new(hw);
    let params = SimParams::from_hw(&hw);
    let mut row_v3 = vec!["UPCv3 (block-cyclic, one-sided)".to_string()];
    let mut row_mpi = vec!["MPI-style (contiguous, two-sided)".to_string()];
    let mut row_mpi_m = vec!["MPI-style model prediction".to_string()];
    for &nodes in &nodes_list {
        let threads = nodes * 16;
        let bs = crate::coordinator::RunConfig::paper_blocksize(threads, cfg.scale_div)
            .min(m.n)
            .max(1);
        let layout = Layout::new(m.n, bs, threads);
        let topo = Topology::new(nodes, 16);
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
        let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
        row_v3.push(s2(sim.spmv_iteration(Variant::V3, &inp).total * cfg.iters as f64));
        let mut solver = MpiSolver::new(&m, threads, &x0);
        // One real exchange step on the configured engine: the table's
        // numbers are simulated, but this keeps the actual data path (and
        // its engine selection) exercised by every harness run.
        solver.step_with(cfg.engine);
        let (mpi_sim, mpi_model) = solver.predict_step(&topo, &hw, &params);
        row_mpi.push(s2(mpi_sim * cfg.iters as f64));
        row_mpi_m.push(s2(mpi_model * cfg.iters as f64));
    }
    t.row(row_v3);
    t.row(row_mpi);
    t.row(row_mpi_m);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mpi_baseline_competitive_multinode() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = baseline_mpi(&cfg, &mut ws);
        assert_eq!(t.rows.len(), 3);
        // MPI-style should be in the same ballpark as UPCv3 (within ~4x
        // either way) — the paper's point is that v3 approaches MPI.
        for c in 1..t.headers.len() {
            let v3: f64 = t.rows[0][c].parse().unwrap();
            let mpi: f64 = t.rows[1][c].parse().unwrap();
            let ratio = v3 / mpi;
            assert!((0.25..6.0).contains(&ratio), "col {c}: v3 {v3} mpi {mpi}");
        }
    }
}
