//! `repro validate` — the calibration loop closed: run the four variants on
//! the *real* parallel engine, predict each with the eqs. (5)–(18) models
//! under a calibrated [`HwParams`](crate::machine::HwParams), and report
//! measured vs predicted.
//!
//! This is the Tables-3/4 methodology pointed at the machine running the
//! binary instead of the paper's Abel cluster: "measured" is the wall-clock
//! median of `Engine::Parallel` iterations (one OS thread per UPC thread,
//! real data movement; `--engine seq` times the sequential oracle instead),
//! "predicted" comes from the same closed forms the paper derives, fed with
//! the host's four characteristic parameters. On
//! the shared-memory engine a "remote" operation is a cross-thread memcpy /
//! cache-line transfer — exactly what the host calibration's `W_node_remote`
//! and `τ` measure — so the models remain dimensionally honest.

use super::{HarnessConfig, Workspace};
use crate::comm::Analysis;
use crate::engine::SpmvEngine;
use crate::mesh::{Ordering, TestProblem};
use crate::model::{self, SpmvInputs};
use crate::pgas::{Layout, Topology};
use crate::spmv::{SpmvState, Variant};
use crate::util::fmt::{self, int, Table};
use crate::util::json::Value;
use crate::util::Stats;
use std::time::Instant;

/// One measured-vs-predicted data point.
#[derive(Debug, Clone)]
pub struct ValidationPoint {
    pub problem: TestProblem,
    pub n: usize,
    pub nodes: usize,
    pub threads_per_node: usize,
    pub block_size: usize,
    pub variant: Variant,
    /// Median wall-clock seconds of one engine iteration.
    pub measured: f64,
    /// Model-predicted seconds for one iteration.
    pub predicted: f64,
}

impl ValidationPoint {
    /// Accuracy ratio measured/predicted (1.0 = perfect; the paper's models
    /// land within tens of percent on Abel, §6.3).
    pub fn ratio(&self) -> f64 {
        self.measured / self.predicted
    }
}

/// The full validation outcome: every point plus the rendered artifacts.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub points: Vec<ValidationPoint>,
    pub table: Table,
    /// `BENCH_model.json` document.
    pub json: Value,
}

impl ValidationReport {
    /// Geometric-mean accuracy ratio for one variant across all layouts
    /// (NaN when the variant has no finite points).
    pub fn geomean_ratio(&self, variant: Variant) -> f64 {
        geomean_for(&self.points, variant)
    }
}

fn geomean_for(points: &[ValidationPoint], variant: Variant) -> f64 {
    geomean(points.iter().filter(|p| p.variant == variant).map(ValidationPoint::ratio))
}

fn geomean(ratios: impl Iterator<Item = f64>) -> f64 {
    let mut sum_ln = 0.0f64;
    let mut n = 0usize;
    for r in ratios {
        if r.is_finite() && r > 0.0 {
            sum_ln += r.ln();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        (sum_ln / n as f64).exp()
    }
}

/// The layouts/meshes the validation sweeps: two test problems, single- and
/// two-"node" topologies over the engine's OS threads, and two BLOCKSIZE
/// regimes (the paper schedule and a 4× finer blocking). Thread counts are
/// capped by the host so every logical UPC thread gets a real core.
fn sweep(cfg: &HarnessConfig) -> Vec<(TestProblem, usize, usize, usize)> {
    let host = crate::microbench::host_threads();
    // Largest power of two ≤ min(host, 8): keeps one OS thread per core and
    // the topologies cleanly divisible.
    let mut t_all = 1usize;
    while t_all * 2 <= host.min(8) {
        t_all *= 2;
    }
    let paper_bs = |threads: usize| {
        crate::coordinator::RunConfig::paper_blocksize(threads, cfg.scale_div)
    };
    let mut configs = vec![(TestProblem::Tp1, 1, t_all, paper_bs(t_all))];
    if t_all >= 2 {
        configs.push((TestProblem::Tp1, 2, t_all / 2, (paper_bs(t_all) / 4).max(1)));
        configs.push((TestProblem::Tp2, 1, t_all, (paper_bs(t_all) / 4).max(1)));
        configs.push((TestProblem::Tp2, 2, t_all / 2, paper_bs(t_all)));
    }
    configs
}

/// Run the validation: all four variants on `cfg.engine` (the parallel
/// worker pool unless `--engine seq` asks for the oracle) across the
/// `sweep` layouts, each predicted with `cfg.hw`. `steps` wall-clock
/// samples are taken per point (median reported); one extra warmup
/// iteration primes the pool's workspaces.
pub fn model_validation(cfg: &HarnessConfig, ws: &mut Workspace, steps: usize) -> ValidationReport {
    let steps = steps.max(3);
    let mut points = Vec::new();
    let mut table = Table::new(
        format!(
            "Model validation — {} engine wall-clock vs eqs. (5)–(18), hw={}, scale 1/{}, {} samples/point",
            cfg.engine.name(), cfg.hw_label, cfg.scale_div, steps
        ),
        &[
            "Problem", "n", "Topology", "BLOCKSIZE", "Variant", "measured/iter",
            "predicted/iter", "meas/pred",
        ],
    );
    for (tp, nodes, tpn, bs) in sweep(cfg) {
        let m = ws.matrix(tp, cfg.scale_div, Ordering::Natural);
        let threads = nodes * tpn;
        let bs = bs.min(m.n).max(1);
        let layout = Layout::new(m.n, bs, threads);
        let topo = Topology::new(nodes, tpn);
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
        // All `threads` OS threads contend for this host's memory system
        // simultaneously, so the per-thread bandwidth share is taken at the
        // *total* engine thread count on the saturation curve.
        let hw_run = cfg.hw.with_threads_per_node(threads);
        let inp = SpmvInputs { layout, topo, hw: hw_run, r_nz: m.r_nz, analysis: &analysis };
        let x0 = m.initial_vector(0xCA11B);
        for variant in Variant::ALL {
            let mut engine = SpmvEngine::new(cfg.engine);
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            engine.run(variant, &mut state, Some(&analysis)); // warmup
            state.swap_xy();
            let mut samples = Vec::with_capacity(steps);
            for _ in 0..steps {
                let t0 = Instant::now();
                engine.run(variant, &mut state, Some(&analysis));
                samples.push(t0.elapsed().as_secs_f64());
                state.swap_xy();
            }
            let measured = Stats::from(&samples).p50;
            let predicted = model::predict(variant, &inp).total;
            let point = ValidationPoint {
                problem: tp,
                n: m.n,
                nodes,
                threads_per_node: tpn,
                block_size: bs,
                variant,
                measured,
                predicted,
            };
            table.row(vec![
                tp.name().to_string(),
                int(m.n),
                format!("{nodes}x{tpn}"),
                bs.to_string(),
                variant.name().to_string(),
                fmt::secs(measured),
                fmt::secs(predicted),
                format!("{:.2}x", point.ratio()),
            ]);
            points.push(point);
        }
    }
    // Per-variant accuracy summary (geometric mean across layouts).
    let mut accuracy = Value::obj();
    for variant in Variant::ALL {
        let g = geomean_for(&points, variant);
        table.row(vec![
            "accuracy".to_string(),
            String::new(),
            String::new(),
            String::new(),
            variant.name().to_string(),
            String::new(),
            String::new(),
            format!("{g:.2}x"),
        ]);
        accuracy.set(variant.name(), Value::Num(g));
    }

    let json = report_json(cfg, steps, &points, &accuracy);
    ValidationReport { points, table, json }
}

fn report_json(
    cfg: &HarnessConfig,
    steps: usize,
    points: &[ValidationPoint],
    accuracy: &Value,
) -> Value {
    let mut results = Vec::with_capacity(points.len());
    for p in points {
        let mut o = Value::obj();
        o.set("problem", Value::Str(p.problem.name().to_string()));
        o.set("n", Value::Num(p.n as f64));
        o.set("nodes", Value::Num(p.nodes as f64));
        o.set("threads_per_node", Value::Num(p.threads_per_node as f64));
        o.set("block_size", Value::Num(p.block_size as f64));
        o.set("variant", Value::Str(p.variant.name().to_string()));
        o.set("measured_s_per_iter", Value::Num(p.measured));
        o.set("predicted_s_per_iter", Value::Num(p.predicted));
        o.set("ratio", Value::Num(p.ratio()));
        results.push(o);
    }
    let mut root = Value::obj();
    root.set("bench", Value::Str("validate/model".to_string()));
    root.set("engine", Value::Str(cfg.engine.name().to_string()));
    root.set("hw_source", Value::Str(cfg.hw_label.clone()));
    root.set("hw", cfg.hw.to_json());
    root.set("scale_div", Value::Num(cfg.scale_div as f64));
    root.set("samples_per_point", Value::Num(steps as f64));
    root.set("results", Value::Arr(results));
    root.set("accuracy_geomean", accuracy.clone());
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean([2.0, 0.5].into_iter()) - 1.0).abs() < 1e-12);
        assert!((geomean([4.0].into_iter()) - 4.0).abs() < 1e-12);
        assert!(geomean([f64::NAN].into_iter()).is_nan());
        assert!(geomean(std::iter::empty()).is_nan());
    }

    #[test]
    fn sweep_respects_host_and_scale() {
        let cfg = HarnessConfig::test_sized();
        let configs = sweep(&cfg);
        assert!(!configs.is_empty());
        let host = crate::microbench::host_threads();
        for (_, nodes, tpn, bs) in configs {
            let threads = nodes * tpn;
            assert!(threads.is_power_of_two() && threads <= 8, "{nodes}x{tpn}");
            assert!(threads <= host || host < 2, "{nodes}x{tpn} on {host} cores");
            assert!(bs >= 1);
        }
    }
}
