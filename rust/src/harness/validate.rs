//! `repro validate` — the calibration loop closed: run the four variants on
//! the *real* parallel engine, predict each with the eqs. (5)–(18) models
//! under a calibrated [`HwParams`](crate::machine::HwParams), and report
//! measured vs predicted.
//!
//! This is the Tables-3/4 methodology pointed at the machine running the
//! binary instead of the paper's Abel cluster: "measured" is the wall-clock
//! median of `Engine::Parallel` iterations (one OS thread per UPC thread,
//! real data movement; `--engine seq` times the sequential oracle instead),
//! "predicted" comes from the same closed forms the paper derives, fed with
//! the host's four characteristic parameters. On
//! the shared-memory engine a "remote" operation is a cross-thread memcpy /
//! cache-line transfer — exactly what the host calibration's `W_node_remote`
//! and `τ` measure — so the models remain dimensionally honest.

use super::{HarnessConfig, Workspace};
use crate::comm::Analysis;
use crate::engine::SpmvEngine;
use crate::heat2d::Heat2dSolver;
use crate::mesh::{Ordering, TestProblem};
use crate::model::{self, HeatGrid, SpmvInputs};
use crate::pgas::{Layout, Topology};
use crate::spmv::{SpmvState, Variant};
use crate::stencil3d::{Stencil3dGrid, Stencil3dSolver};
use crate::util::fmt::{self, int, Table};
use crate::util::json::Value;
use crate::util::Stats;
use std::time::Instant;

/// One measured-vs-predicted data point.
#[derive(Debug, Clone)]
pub struct ValidationPoint {
    pub problem: TestProblem,
    pub n: usize,
    pub nodes: usize,
    pub threads_per_node: usize,
    pub block_size: usize,
    pub variant: Variant,
    /// Median wall-clock seconds of one engine iteration.
    pub measured: f64,
    /// Model-predicted seconds for one iteration.
    pub predicted: f64,
}

impl ValidationPoint {
    /// Accuracy ratio measured/predicted (1.0 = perfect; the paper's models
    /// land within tens of percent on Abel, §6.3).
    pub fn ratio(&self) -> f64 {
        self.measured / self.predicted
    }
}

/// Workload labels validated through [`WorkloadPoint`]s: the two grid
/// workloads, their split-phase overlapped steps
/// (`T_step ≈ T_pack + max(T_transfer, T_comp^int) + T_unpack +
/// T_comp^bnd`), their multi-step pipelined batches
/// (`T_total ≈ S·max(T_comm, T_serial) + fill/drain`, reported per step),
/// and the overlapped/pipelined SpMV V3.
pub const WORKLOAD_LABELS: [&str; 8] = [
    "heat2d",
    "heat2d-ovl",
    "heat2d-pipe",
    "stencil3d",
    "stencil3d-ovl",
    "stencil3d-pipe",
    "spmv-v3-ovl",
    "spmv-v3-pipe",
];

/// One measured-vs-predicted point for a workload on the exchange runtime
/// (heat-2D, the 3D stencil, their overlapped variants, overlapped SpMV).
#[derive(Debug, Clone)]
pub struct WorkloadPoint {
    /// One of [`WORKLOAD_LABELS`].
    pub workload: &'static str,
    /// Human-readable geometry, e.g. `"624x624 / 2x4"`.
    pub geometry: String,
    /// Interior cells per step.
    pub cells: usize,
    pub nodes: usize,
    pub threads_per_node: usize,
    /// Median wall-clock seconds of one solver step.
    pub measured: f64,
    /// Model-predicted seconds for one step (halo + compute).
    pub predicted: f64,
}

impl WorkloadPoint {
    pub fn ratio(&self) -> f64 {
        self.measured / self.predicted
    }
}

/// The full validation outcome: every point plus the rendered artifacts.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub points: Vec<ValidationPoint>,
    /// Grid workloads on the exchange runtime, same methodology.
    pub workloads: Vec<WorkloadPoint>,
    pub table: Table,
    /// `BENCH_model.json` document.
    pub json: Value,
}

impl ValidationReport {
    /// Geometric-mean accuracy ratio for one variant across all layouts
    /// (NaN when the variant has no finite points).
    pub fn geomean_ratio(&self, variant: Variant) -> f64 {
        geomean_for(&self.points, variant)
    }

    /// Geometric-mean accuracy ratio for one grid workload.
    pub fn workload_geomean(&self, workload: &str) -> f64 {
        geomean(self.workloads.iter().filter(|p| p.workload == workload).map(WorkloadPoint::ratio))
    }
}

fn geomean_for(points: &[ValidationPoint], variant: Variant) -> f64 {
    geomean(points.iter().filter(|p| p.variant == variant).map(ValidationPoint::ratio))
}

fn geomean(ratios: impl Iterator<Item = f64>) -> f64 {
    let mut sum_ln = 0.0f64;
    let mut n = 0usize;
    for r in ratios {
        if r.is_finite() && r > 0.0 {
            sum_ln += r.ln();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        (sum_ln / n as f64).exp()
    }
}

/// The layouts/meshes the validation sweeps: two test problems, single- and
/// two-"node" topologies over the engine's OS threads, and two BLOCKSIZE
/// regimes (the paper schedule and a 4× finer blocking). Thread counts are
/// capped by the host so every logical UPC thread gets a real core.
fn sweep(cfg: &HarnessConfig) -> Vec<(TestProblem, usize, usize, usize)> {
    let t_all = host_pow2_threads();
    let paper_bs = |threads: usize| {
        crate::coordinator::RunConfig::paper_blocksize(threads, cfg.scale_div)
    };
    let mut configs = vec![(TestProblem::Tp1, 1, t_all, paper_bs(t_all))];
    if t_all >= 2 {
        configs.push((TestProblem::Tp1, 2, t_all / 2, (paper_bs(t_all) / 4).max(1)));
        configs.push((TestProblem::Tp2, 1, t_all, (paper_bs(t_all) / 4).max(1)));
        configs.push((TestProblem::Tp2, 2, t_all / 2, paper_bs(t_all)));
    }
    configs
}

/// Largest power of two ≤ min(host cores, 8): one OS thread per core and
/// cleanly divisible topologies.
fn host_pow2_threads() -> usize {
    let host = crate::microbench::host_threads();
    let mut t_all = 1usize;
    while t_all * 2 <= host.min(8) {
        t_all *= 2;
    }
    t_all
}

/// Median wall-clock seconds of one `step()` call, after one warmup step
/// (which spawns the persistent pool and primes its workspaces). The one
/// sampling protocol every grid workload is measured with.
fn median_step_seconds(mut step: impl FnMut(), steps: usize) -> f64 {
    step(); // warmup
    let mut samples = Vec::with_capacity(steps);
    for _ in 0..steps {
        let t0 = Instant::now();
        step();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from(&samples).p50
}

/// The SpMV sampling protocol: median of `steps` timed samples after one
/// discarded warmup sample. `sample` runs one engine iteration and returns
/// its timed seconds — work it does after stopping the clock (the `swap_xy`
/// between iterations) stays untimed. Shared by the per-variant and the
/// overlapped measurement so both columns use one methodology.
fn median_sample_seconds(steps: usize, mut sample: impl FnMut() -> f64) -> f64 {
    sample(); // warmup: primes the pool + workspaces
    let samples: Vec<f64> = (0..steps).map(|_| sample()).collect();
    Stats::from(&samples).p50
}

/// Measure the grid workloads (heat-2D and the 3D stencil, both on the
/// shared exchange runtime) and predict each with the eqs. (19)–(22)
/// models — synchronous, split-phase overlapped, and multi-step pipelined
/// (batches of `pipeline` steps at buffer depth `depth`, reported per
/// step). One solver per workload/protocol through
/// [`median_step_seconds`]; the median is compared against each sweep
/// topology's prediction.
fn workload_validation(
    cfg: &HarnessConfig,
    steps: usize,
    pipeline: usize,
    depth: usize,
) -> Vec<WorkloadPoint> {
    let pipeline = pipeline.max(1);
    let depth = depth.max(1);
    let t_all = host_pow2_threads();
    let hw_run = cfg.hw.with_threads_per_node(t_all);
    let mut topos = vec![(1usize, t_all)];
    if t_all >= 2 {
        topos.push((2, t_all / 2));
    }
    // Round a global extent down to a multiple of the axis split, keeping
    // at least 4 cells per subdomain.
    let fit = |g: usize, parts: usize| ((g / parts).max(4)) * parts;
    let mut out = Vec::new();

    // heat-2D on a near-square thread grid, mesh scaled like the problems.
    let (mp, np) = {
        let mut mp = 1usize;
        while mp * 2 * mp <= t_all {
            mp *= 2;
        }
        (mp, t_all / mp)
    };
    let base2 = (20_000 / cfg.scale_div.max(1)).clamp(8, 4096);
    let grid2 = HeatGrid::new(fit(base2, mp), fit(base2, np), mp, np);
    let mut rng = crate::util::Rng::new(0x41EA7);
    let f0: Vec<f64> = (0..grid2.m_glob * grid2.n_glob).map(|_| rng.f64_in(0.0, 100.0)).collect();
    let mut solver = Heat2dSolver::new(grid2, &f0);
    let measured = median_step_seconds(|| solver.step_with(cfg.engine), steps);
    let mut solver_ovl = Heat2dSolver::new(grid2, &f0);
    let measured_ovl =
        median_step_seconds(|| solver_ovl.step_overlapped_with(cfg.engine), steps);
    let mut solver_pipe = Heat2dSolver::new(grid2, &f0);
    solver_pipe.set_depth(depth);
    let measured_pipe =
        median_step_seconds(|| solver_pipe.run_pipelined_with(cfg.engine, pipeline), steps)
            / pipeline as f64;
    for &(nodes, tpn) in &topos {
        let topo = Topology::new(nodes, tpn);
        let p = model::predict_heat2d(&grid2, &topo, &hw_run);
        let geometry = format!("{}x{} / {mp}x{np}", grid2.m_glob, grid2.n_glob);
        out.push(WorkloadPoint {
            workload: "heat2d",
            geometry: geometry.clone(),
            cells: grid2.m_glob * grid2.n_glob,
            nodes,
            threads_per_node: tpn,
            measured,
            predicted: p.t_halo + p.t_comp,
        });
        let p_ovl = model::predict_heat2d_overlap(&grid2, &topo, &hw_run);
        out.push(WorkloadPoint {
            workload: "heat2d-ovl",
            geometry: geometry.clone(),
            cells: grid2.m_glob * grid2.n_glob,
            nodes,
            threads_per_node: tpn,
            measured: measured_ovl,
            predicted: p_ovl.t_step,
        });
        let p_pipe =
            model::PipelinePrediction::from_overlap_depth(&p_ovl, pipeline, depth, hw_run.tau);
        out.push(WorkloadPoint {
            workload: "heat2d-pipe",
            geometry,
            cells: grid2.m_glob * grid2.n_glob,
            nodes,
            threads_per_node: tpn,
            measured: measured_pipe,
            predicted: p_pipe.t_per_step,
        });
    }

    // 3D stencil: split the same thread budget across three axes.
    let (pp, mp3, np3) = {
        let l = t_all.trailing_zeros() as usize;
        let pp = 1usize << (l / 3);
        let mp3 = 1usize << ((l + 1) / 3);
        (pp, mp3, t_all / (pp * mp3))
    };
    let base3 = (2_560 / cfg.scale_div.max(1)).clamp(10, 192);
    let grid3 = Stencil3dGrid::new(
        fit(base3, pp),
        fit(base3, mp3),
        fit(base3, np3),
        pp,
        mp3,
        np3,
    );
    let f0: Vec<f64> = (0..grid3.p_glob * grid3.m_glob * grid3.n_glob)
        .map(|_| rng.f64_in(0.0, 100.0))
        .collect();
    let mut solver = Stencil3dSolver::new(grid3, &f0);
    let measured = median_step_seconds(|| solver.step_with(cfg.engine), steps);
    let mut solver_ovl = Stencil3dSolver::new(grid3, &f0);
    let measured_ovl =
        median_step_seconds(|| solver_ovl.step_overlapped_with(cfg.engine), steps);
    let mut solver_pipe = Stencil3dSolver::new(grid3, &f0);
    solver_pipe.set_depth(depth);
    let measured_pipe =
        median_step_seconds(|| solver_pipe.run_pipelined_with(cfg.engine, pipeline), steps)
            / pipeline as f64;
    for &(nodes, tpn) in &topos {
        let topo = Topology::new(nodes, tpn);
        let p = model::predict_stencil3d(&grid3, &topo, &hw_run);
        let geometry = format!(
            "{}x{}x{} / {pp}x{mp3}x{np3}",
            grid3.p_glob, grid3.m_glob, grid3.n_glob
        );
        out.push(WorkloadPoint {
            workload: "stencil3d",
            geometry: geometry.clone(),
            cells: grid3.p_glob * grid3.m_glob * grid3.n_glob,
            nodes,
            threads_per_node: tpn,
            measured,
            predicted: p.t_halo + p.t_comp,
        });
        let p_ovl = model::predict_stencil3d_overlap(&grid3, &topo, &hw_run);
        out.push(WorkloadPoint {
            workload: "stencil3d-ovl",
            geometry: geometry.clone(),
            cells: grid3.p_glob * grid3.m_glob * grid3.n_glob,
            nodes,
            threads_per_node: tpn,
            measured: measured_ovl,
            predicted: p_ovl.t_step,
        });
        let p_pipe =
            model::PipelinePrediction::from_overlap_depth(&p_ovl, pipeline, depth, hw_run.tau);
        out.push(WorkloadPoint {
            workload: "stencil3d-pipe",
            geometry,
            cells: grid3.p_glob * grid3.m_glob * grid3.n_glob,
            nodes,
            threads_per_node: tpn,
            measured: measured_pipe,
            predicted: p_pipe.t_per_step,
        });
    }
    out
}

/// Labels of the buffer-depth sweep rows, D = 1..=4.
const DEPTH_SWEEP_LABELS: [&str; 4] =
    ["heat2d-pipe-d1", "heat2d-pipe-d2", "heat2d-pipe-d3", "heat2d-pipe-d4"];

/// The heat-2D grid and rescaled parameters behind the buffer-depth sweep
/// rows: `(grid, hw_run, threads, mp, np)`. Shared with
/// [`model_chosen_depth`] so the recorded `--depth auto` pick is evaluated
/// on exactly the configuration the sweep measures.
fn depth_sweep_setup(
    cfg: &HarnessConfig,
) -> (HeatGrid, crate::machine::HwParams, usize, usize, usize) {
    let t_all = host_pow2_threads();
    let hw_run = cfg.hw.with_threads_per_node(t_all);
    let (mp, np) = {
        let mut mp = 1usize;
        while mp * 2 * mp <= t_all {
            mp *= 2;
        }
        (mp, t_all / mp)
    };
    let fit = |g: usize, parts: usize| ((g / parts).max(4)) * parts;
    let base = (2_048 / cfg.scale_div.max(1)).clamp(8, 512);
    let grid = HeatGrid::new(fit(base, mp), fit(base, np), mp, np);
    (grid, hw_run, t_all, mp, np)
}

/// The model's `--depth auto` pick: the
/// [`choose_depth`](crate::model::choose_depth) sweep over the depth-sweep
/// grid's overlap prediction at batch size `pipeline`. `repro validate
/// --depth auto` runs with this depth, and every validation records it in
/// `BENCH_model.json` (`depth_model_choice`) next to the depth it ran.
pub fn model_chosen_depth(cfg: &HarnessConfig, pipeline: usize) -> usize {
    let (grid, hw_run, t_all, _, _) = depth_sweep_setup(cfg);
    let topo = Topology::new(1, t_all);
    let ovl = model::predict_heat2d_overlap(&grid, &topo, &hw_run);
    model::choose_depth(&ovl, pipeline.max(1), hw_run.tau).0
}

/// The raw-speed section: measured-vs-predicted rows that exercise the
/// kernel tier and the buffered pipeline directly. Their labels are *not*
/// in [`WORKLOAD_LABELS`], so they are reported (table + JSON) without
/// feeding the legacy geomean budget gate.
///
/// 1. `pack-kernel` — one indexed gather+scatter round trip
///    ([`pack_bandwidth_host`](crate::microbench::pack_bandwidth_host))
///    against the model's `W_pack` stream time. With `--hw host` the
///    parameter was calibrated by the same probe, so the ratio doubles as
///    a calibration self-check.
/// 2. `heat2d-pipe-dD` for D = 1..4 — pipelined heat-2D batches at each
///    buffer depth against
///    [`from_overlap_depth`](crate::model::PipelinePrediction::from_overlap_depth),
///    the sweep [`choose_depth`](crate::model::choose_depth) optimizes
///    over.
fn raw_speed_validation(cfg: &HarnessConfig, steps: usize, pipeline: usize) -> Vec<WorkloadPoint> {
    let pipeline = pipeline.max(1);
    let mut out = Vec::new();

    // Kernel tier. The probe is single-threaded, as the calibration was,
    // so the un-rescaled `cfg.hw` is the honest comparison point.
    let probe_elems = 1usize << 20;
    let probe = crate::microbench::pack_bandwidth_host(probe_elems, 3);
    out.push(WorkloadPoint {
        workload: "pack-kernel",
        geometry: format!("{} doubles round trip", int(probe_elems)),
        cells: probe_elems,
        nodes: 1,
        threads_per_node: 1,
        measured: probe.seconds,
        predicted: cfg.hw.t_pack_stream(probe.bytes),
    });

    // Buffer-depth sweep on pipelined heat-2D: one solver per depth, the
    // same batch size and sampling protocol as the `heat2d-pipe` row.
    let (grid, hw_run, t_all, mp, np) = depth_sweep_setup(cfg);
    let mut rng = crate::util::Rng::new(0xD3F7);
    let f0: Vec<f64> = (0..grid.m_glob * grid.n_glob).map(|_| rng.f64_in(0.0, 100.0)).collect();
    let topo = Topology::new(1, t_all);
    let ovl = model::predict_heat2d_overlap(&grid, &topo, &hw_run);
    let geometry = format!("{}x{} / {mp}x{np}", grid.m_glob, grid.n_glob);
    for (i, &label) in DEPTH_SWEEP_LABELS.iter().enumerate() {
        let depth = i + 1;
        let mut solver = Heat2dSolver::new(grid, &f0);
        solver.set_depth(depth);
        let measured =
            median_step_seconds(|| solver.run_pipelined_with(cfg.engine, pipeline), steps)
                / pipeline as f64;
        let p = model::PipelinePrediction::from_overlap_depth(&ovl, pipeline, depth, hw_run.tau);
        out.push(WorkloadPoint {
            workload: label,
            geometry: geometry.clone(),
            cells: grid.m_glob * grid.n_glob,
            nodes: 1,
            threads_per_node: t_all,
            measured,
            predicted: p.t_per_step,
        });
    }
    out
}

/// Run the validation: all four variants on `cfg.engine` (the parallel
/// worker pool unless `--engine seq` asks for the oracle) across the
/// `sweep` layouts, each predicted with `cfg.hw`, plus the heat-2D and
/// 3D-stencil workloads on the exchange runtime — each in synchronous,
/// overlapped, and pipelined (`pipeline`-step batches at buffer depth
/// `depth`) form, and the raw-speed section (pack-kernel bandwidth and a
/// D = 1..4 buffer-depth sweep, report-only). `steps` wall-clock samples
/// are taken per point (median reported); one extra warmup iteration
/// primes the pool's workspaces.
pub fn model_validation(
    cfg: &HarnessConfig,
    ws: &mut Workspace,
    steps: usize,
    pipeline: usize,
    depth: usize,
) -> ValidationReport {
    let steps = steps.max(3);
    let pipeline = pipeline.max(1);
    let depth = depth.max(1);
    let mut points = Vec::new();
    let mut spmv_overlap: Vec<WorkloadPoint> = Vec::new();
    let mut table = Table::new(
        format!(
            "Model validation — {} engine wall-clock vs eqs. (5)–(18), hw={}, scale 1/{}, {} samples/point, {}-step pipeline batches, depth {}",
            cfg.engine.name(), cfg.hw_label, cfg.scale_div, steps, pipeline, depth
        ),
        &[
            "Problem", "n", "Topology", "BLOCKSIZE", "Variant", "measured/iter",
            "predicted/iter", "meas/pred",
        ],
    );
    for (tp, nodes, tpn, bs) in sweep(cfg) {
        let m = ws.matrix(tp, cfg.scale_div, Ordering::Natural);
        let threads = nodes * tpn;
        let bs = bs.min(m.n).max(1);
        let layout = Layout::new(m.n, bs, threads);
        let topo = Topology::new(nodes, tpn);
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
        // All `threads` OS threads contend for this host's memory system
        // simultaneously, so the per-thread bandwidth share is taken at the
        // *total* engine thread count on the saturation curve.
        let hw_run = cfg.hw.with_threads_per_node(threads);
        let inp = SpmvInputs { layout, topo, hw: hw_run, r_nz: m.r_nz, analysis: &analysis };
        let x0 = m.initial_vector(0xCA11B);
        for variant in Variant::ALL {
            let mut engine = SpmvEngine::new(cfg.engine);
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            let measured = median_sample_seconds(steps, || {
                let t0 = Instant::now();
                engine.run(variant, &mut state, Some(&analysis));
                let dt = t0.elapsed().as_secs_f64();
                state.swap_xy();
                dt
            });
            let predicted = model::predict(variant, &inp).total;
            let point = ValidationPoint {
                problem: tp,
                n: m.n,
                nodes,
                threads_per_node: tpn,
                block_size: bs,
                variant,
                measured,
                predicted,
            };
            table.row(vec![
                tp.name().to_string(),
                int(m.n),
                format!("{nodes}x{tpn}"),
                bs.to_string(),
                variant.name().to_string(),
                fmt::secs(measured),
                fmt::secs(predicted),
                format!("{:.2}x", point.ratio()),
            ]);
            points.push(point);
        }
        // Split-phase overlapped V3 on the same layout: measured against
        // the overlap model T_step ≈ max(T_comm, T_comp^int) + T_comp^bnd.
        {
            let mut engine = SpmvEngine::new(cfg.engine);
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            let measured = median_sample_seconds(steps, || {
                let t0 = Instant::now();
                engine.run_overlapped(&mut state, &analysis);
                let dt = t0.elapsed().as_secs_f64();
                state.swap_xy();
                dt
            });
            let predicted = model::predict_overlapped(Variant::V3, &inp).t_step;
            spmv_overlap.push(WorkloadPoint {
                workload: "spmv-v3-ovl",
                geometry: format!("{} n={}", tp.name(), m.n),
                cells: m.n,
                nodes,
                threads_per_node: tpn,
                measured,
                predicted,
            });
        }
        // Multi-step pipelined V3: one `pipeline`-step batch per timed
        // sample (a single pool dispatch), reported per step against the
        // pipeline model.
        {
            let mut engine = SpmvEngine::new(cfg.engine);
            engine.set_depth(depth);
            let mut state = SpmvState::new(&m, bs, threads, &x0);
            let measured = median_sample_seconds(steps, || {
                let t0 = Instant::now();
                engine.run_pipelined(pipeline, &mut state, &analysis);
                let dt = t0.elapsed().as_secs_f64();
                state.swap_xy();
                dt
            }) / pipeline as f64;
            let ovl = model::predict_overlapped(Variant::V3, &inp);
            let predicted =
                model::PipelinePrediction::from_overlap_depth(&ovl, pipeline, depth, hw_run.tau)
                    .t_per_step;
            spmv_overlap.push(WorkloadPoint {
                workload: "spmv-v3-pipe",
                geometry: format!("{} n={}", tp.name(), m.n),
                cells: m.n,
                nodes,
                threads_per_node: tpn,
                measured,
                predicted,
            });
        }
    }
    // Grid workloads on the exchange runtime: same measured-vs-predicted
    // methodology, one row per sweep topology — synchronous, overlapped,
    // and pipelined.
    let mut workloads = workload_validation(cfg, steps, pipeline, depth);
    workloads.extend(spmv_overlap);
    // Raw-speed rows (labels outside [`WORKLOAD_LABELS`], so they report
    // without entering the legacy geomean gate): the indexed pack/unpack
    // kernel against the calibrated W_pack, and a D = 1..4 buffer-depth
    // sweep against the depth-aware pipeline model.
    workloads.extend(raw_speed_validation(cfg, steps, pipeline));
    for p in &workloads {
        table.row(vec![
            p.workload.to_string(),
            p.geometry.clone(),
            format!("{}x{}", p.nodes, p.threads_per_node),
            "-".to_string(),
            "halo+comp".to_string(),
            fmt::secs(p.measured),
            fmt::secs(p.predicted),
            format!("{:.2}x", p.ratio()),
        ]);
    }

    // Per-variant accuracy summary (geometric mean across layouts).
    let mut accuracy = Value::obj();
    for variant in Variant::ALL {
        let g = geomean_for(&points, variant);
        table.row(vec![
            "accuracy".to_string(),
            String::new(),
            String::new(),
            String::new(),
            variant.name().to_string(),
            String::new(),
            String::new(),
            format!("{g:.2}x"),
        ]);
        accuracy.set(variant.name(), Value::Num(g));
    }
    let mut workload_accuracy = Value::obj();
    for w in WORKLOAD_LABELS {
        let g = geomean(workloads.iter().filter(|p| p.workload == w).map(WorkloadPoint::ratio));
        table.row(vec![
            "accuracy".to_string(),
            String::new(),
            String::new(),
            String::new(),
            w.to_string(),
            String::new(),
            String::new(),
            format!("{g:.2}x"),
        ]);
        workload_accuracy.set(w, Value::Num(g));
    }

    let json = report_json(
        cfg,
        steps,
        pipeline,
        depth,
        model_chosen_depth(cfg, pipeline),
        &points,
        &workloads,
        &accuracy,
        &workload_accuracy,
    );
    ValidationReport { points, workloads, table, json }
}

#[allow(clippy::too_many_arguments)]
fn report_json(
    cfg: &HarnessConfig,
    steps: usize,
    pipeline: usize,
    depth: usize,
    depth_model_choice: usize,
    points: &[ValidationPoint],
    workloads: &[WorkloadPoint],
    accuracy: &Value,
    workload_accuracy: &Value,
) -> Value {
    let mut results = Vec::with_capacity(points.len());
    for p in points {
        let mut o = Value::obj();
        o.set("problem", Value::Str(p.problem.name().to_string()));
        o.set("n", Value::Num(p.n as f64));
        o.set("nodes", Value::Num(p.nodes as f64));
        o.set("threads_per_node", Value::Num(p.threads_per_node as f64));
        o.set("block_size", Value::Num(p.block_size as f64));
        o.set("variant", Value::Str(p.variant.name().to_string()));
        o.set("measured_s_per_iter", Value::Num(p.measured));
        o.set("predicted_s_per_iter", Value::Num(p.predicted));
        o.set("ratio", Value::Num(p.ratio()));
        results.push(o);
    }
    let mut root = Value::obj();
    root.set("bench", Value::Str("validate/model".to_string()));
    root.set("engine", Value::Str(cfg.engine.name().to_string()));
    root.set("hw_source", Value::Str(cfg.hw_label.clone()));
    root.set("hw", cfg.hw.to_json());
    root.set("scale_div", Value::Num(cfg.scale_div as f64));
    root.set("samples_per_point", Value::Num(steps as f64));
    root.set("pipeline_steps", Value::Num(pipeline as f64));
    root.set("depth", Value::Num(depth as f64));
    root.set("depth_model_choice", Value::Num(depth_model_choice as f64));
    root.set("results", Value::Arr(results));
    let mut wl = Vec::with_capacity(workloads.len());
    for p in workloads {
        let mut o = Value::obj();
        o.set("workload", Value::Str(p.workload.to_string()));
        o.set("geometry", Value::Str(p.geometry.clone()));
        o.set("cells", Value::Num(p.cells as f64));
        o.set("nodes", Value::Num(p.nodes as f64));
        o.set("threads_per_node", Value::Num(p.threads_per_node as f64));
        o.set("measured_s_per_step", Value::Num(p.measured));
        o.set("predicted_s_per_step", Value::Num(p.predicted));
        o.set("ratio", Value::Num(p.ratio()));
        wl.push(o);
    }
    root.set("workloads", Value::Arr(wl));
    root.set("accuracy_geomean", accuracy.clone());
    root.set("workload_accuracy_geomean", workload_accuracy.clone());
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean([2.0, 0.5].into_iter()) - 1.0).abs() < 1e-12);
        assert!((geomean([4.0].into_iter()) - 4.0).abs() < 1e-12);
        assert!(geomean([f64::NAN].into_iter()).is_nan());
        assert!(geomean(std::iter::empty()).is_nan());
    }

    #[test]
    fn workload_points_cover_both_grid_workloads() {
        let cfg = HarnessConfig::test_sized();
        let points = workload_validation(&cfg, 3, 4, 2);
        // Both grid workloads, each in synchronous, overlapped, and
        // pipelined form.
        for w in [
            "heat2d",
            "heat2d-ovl",
            "heat2d-pipe",
            "stencil3d",
            "stencil3d-ovl",
            "stencil3d-pipe",
        ] {
            assert!(points.iter().any(|p| p.workload == w), "missing {w}");
        }
        for p in &points {
            assert!(p.measured > 0.0, "{}: non-positive measurement", p.workload);
            assert!(p.predicted > 0.0, "{}: non-positive prediction", p.workload);
            assert!(p.ratio().is_finite());
        }
    }

    #[test]
    fn raw_speed_rows_are_finite_and_gate_free() {
        let cfg = HarnessConfig::test_sized();
        let points = raw_speed_validation(&cfg, 3, 4);
        assert!(points.iter().any(|p| p.workload == "pack-kernel"));
        for label in DEPTH_SWEEP_LABELS {
            assert!(points.iter().any(|p| p.workload == label), "missing {label}");
        }
        for p in &points {
            assert!(p.measured > 0.0, "{}: non-positive measurement", p.workload);
            assert!(p.predicted > 0.0, "{}: non-positive prediction", p.workload);
            assert!(p.ratio().is_finite(), "{}", p.workload);
            // None of these labels may leak into the budget-gated set.
            assert!(!WORKLOAD_LABELS.contains(&p.workload), "{} gated", p.workload);
        }
    }

    #[test]
    fn sweep_respects_host_and_scale() {
        let cfg = HarnessConfig::test_sized();
        let configs = sweep(&cfg);
        assert!(!configs.is_empty());
        let host = crate::microbench::host_threads();
        for (_, nodes, tpn, bs) in configs {
            let threads = nodes * tpn;
            assert!(threads.is_power_of_two() && threads <= 8, "{nodes}x{tpn}");
            assert!(threads <= host || host < 2, "{nodes}x{tpn} on {host} cores");
            assert!(bs >= 1);
        }
    }
}
