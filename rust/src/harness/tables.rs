//! Tables 1–5 of the paper.

use super::{s2, HarnessConfig, Workspace};
use crate::comm::Analysis;
use crate::heat2d::{partition_for, simulate_heat_step};
use crate::machine::HwParams;
use crate::mesh::{Ordering, TestProblem};
use crate::microbench;
use crate::model::{self, HeatGrid, SpmvInputs};
use crate::pgas::{Layout, Topology};
use crate::sim::{ClusterSim, SimParams};
use crate::spmv::Variant;
use crate::util::fmt::{int, Table};

/// Table 1: sizes of the three test problems (paper vs generated).
pub fn table1(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let mut t = Table::new(
        format!("Table 1 — test problem sizes (scale 1/{})", cfg.scale_div),
        &["", "Test problem 1", "Test problem 2", "Test problem 3"],
    );
    t.row(vec![
        "Paper n (tetrahedra)".into(),
        int(TestProblem::Tp1.paper_n()),
        int(TestProblem::Tp2.paper_n()),
        int(TestProblem::Tp3.paper_n()),
    ]);
    let gen: Vec<String> = TestProblem::ALL
        .iter()
        .map(|&tp| int(ws.mesh(tp, cfg.scale_div, Ordering::Natural).n))
        .collect();
    t.row({
        let mut r = vec![format!("Generated n (1/{})", cfg.scale_div)];
        r.extend(gen);
        r
    });
    t
}

/// Shared helper: per-iteration simulated total for one configuration. The
/// injected `hw` is rescaled to the topology's threads-per-node (§5.1).
fn sim_total(
    ws: &mut Workspace,
    cfg: &HarnessConfig,
    tp: TestProblem,
    variant: Variant,
    nodes: usize,
    tpn: usize,
    block_size: usize,
    hw: &HwParams,
) -> f64 {
    let m = ws.matrix(tp, cfg.scale_div, Ordering::Natural);
    let layout = Layout::new(m.n, block_size.min(m.n).max(1), nodes * tpn);
    let topo = Topology::new(nodes, tpn);
    let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
    let hw = hw.with_threads_per_node(tpn);
    let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
    let sim = ClusterSim::new(hw);
    sim.spmv_iteration(variant, &inp).total * cfg.iters as f64
}

/// Table 2: naive vs UPCv1 on one node, 1–16 threads, Test problem 1.
pub fn table2(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let threads = [1usize, 2, 4, 8, 16];
    let mut t = Table::new(
        format!(
            "Table 2 — naive vs UPCv1, 1 node, TP1, BLOCKSIZE={}, {} iters (simulated)",
            65_536 / cfg.scale_div,
            cfg.iters
        ),
        &["", "1 thread", "2 threads", "4 threads", "8 threads", "16 threads"],
    );
    let bs = (65_536 / cfg.scale_div).max(1);
    for variant in [Variant::Naive, Variant::V1] {
        let mut row = vec![variant.name().to_string()];
        for &nt in &threads {
            // sim_total rescales the per-thread bandwidth share to the
            // nt-thread node (paper §5.1).
            row.push(s2(sim_total(ws, cfg, TestProblem::Tp1, variant, 1, nt, bs, &cfg.hw)));
        }
        t.row(row);
    }
    // Paper reference rows (measured on Abel at full scale).
    t.row(vec!["paper: Naive UPC".into(), "895.44".into(), "548.57".into(), "301.17".into(), "173.08".into(), "106.10".into()]);
    t.row(vec!["paper: UPCv1".into(), "270.40".into(), "159.51".into(), "86.37".into(), "51.10".into(), "28.80".into()]);
    t
}

const NODE_COUNTS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Table 3: the three transformed variants across 1–64 nodes for all three
/// test problems.
pub fn table3(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let mut headers: Vec<String> = vec!["".into()];
    headers.extend(NODE_COUNTS.iter().map(|n| format!("{n} node{}", if *n > 1 { "s" } else { "" })));
    let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        format!("Table 3 — {} iters SpMV, 16 threads/node (simulated)", cfg.iters),
        &headers_ref,
    );
    for tp in TestProblem::ALL {
        let n = ws.mesh(tp, cfg.scale_div, Ordering::Natural).n;
        t.row({
            let mut r = vec![format!("{}: n={}", tp.name(), int(n))];
            r.extend(std::iter::repeat_n(String::new(), NODE_COUNTS.len()));
            r
        });
        for variant in Variant::TRANSFORMED {
            let mut row = vec![format!("  {}", variant.name())];
            for &nodes in &NODE_COUNTS {
                let bs = crate::coordinator::RunConfig::paper_blocksize(nodes * 16, cfg.scale_div);
                row.push(s2(sim_total(ws, cfg, tp, variant, nodes, 16, bs, &cfg.hw)));
            }
            t.row(row);
        }
    }
    t
}

/// Table 4: actual (simulated) vs predicted (model) for Test problem 1.
pub fn table4(cfg: &HarnessConfig, ws: &mut Workspace) -> Table {
    let mut t = Table::new(
        format!(
            "Table 4 — actual (sim) vs predicted (model), TP1, {} iters",
            cfg.iters
        ),
        &[
            "THREADS", "BLOCKSIZE", "v1 actual", "v1 predicted", "v2 actual", "v2 predicted",
            "v3 actual", "v3 predicted",
        ],
    );
    let m = ws.matrix(TestProblem::Tp1, cfg.scale_div, Ordering::Natural);
    let hw = cfg.hw_for_tpn(16);
    let sim = ClusterSim::new(hw);
    for &nodes in &NODE_COUNTS {
        let threads = nodes * 16;
        let bs = crate::coordinator::RunConfig::paper_blocksize(threads, cfg.scale_div)
            .min(m.n)
            .max(1);
        let layout = Layout::new(m.n, bs, threads);
        let topo = Topology::new(nodes, 16);
        let analysis = Analysis::build(&m.j, m.r_nz, layout, topo, cfg.cache_window());
        let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &analysis };
        let mut row = vec![threads.to_string(), bs.to_string()];
        for variant in Variant::TRANSFORMED {
            let actual = sim.spmv_iteration(variant, &inp).total * cfg.iters as f64;
            let predicted = model::predict(variant, &inp).total * cfg.iters as f64;
            row.push(s2(actual));
            row.push(s2(predicted));
        }
        t.row(row);
    }
    t
}

/// Table 5: the 2D heat solver, actual (simulated) vs predicted, both paper
/// meshes. Dimensions are *not* scaled — these rows are purely analytic.
pub fn table5(cfg: &HarnessConfig) -> Table {
    let mut t = Table::new(
        format!("Table 5 — 2D heat equation, {} steps (sim vs model)", cfg.iters),
        &[
            "Mesh", "THREADS", "Partitioning", "T_halo actual", "T_halo predicted",
            "T_comp actual", "T_comp predicted",
        ],
    );
    // Table 5's schedule always runs 16 threads/node.
    let hw = cfg.hw_for_tpn(16);
    let params = SimParams::from_hw(&hw);
    for &(mg, ng) in &[(20_000usize, 20_000usize), (40_000, 40_000)] {
        for &threads in &[16usize, 32, 64, 128, 256, 512] {
            let (mp, np) = partition_for(threads).expect("schedule");
            let grid = HeatGrid::new(mg, ng, mp, np);
            let topo = Topology::new((threads / 16).max(1), threads.min(16));
            let sim = simulate_heat_step(&grid, &topo, &hw, &params);
            let model = model::predict_heat2d(&grid, &topo, &hw);
            let k = cfg.iters as f64;
            t.row(vec![
                format!("{mg}x{ng}"),
                threads.to_string(),
                format!("{mp}x{np}"),
                s2(sim.t_halo * k),
                s2(model.t_halo * k),
                s2(sim.t_comp * k),
                s2(model.t_comp * k),
            ]);
        }
    }
    t
}

/// §6.2: the microbenchmark table — recovered hardware constants. The
/// "Paper / injected" column is derived from `cfg.hw`, so the recovery
/// check is meaningful for *any* injected parameter set (host calibrations,
/// calibration files), not just the Abel defaults.
pub fn microbench_table(cfg: &HarnessConfig) -> Table {
    let mut t = Table::new(
        format!(
            "§6.2 microbenchmarks — recovered hardware constants (simulated cluster, hw={})",
            cfg.hw_label
        ),
        &["Benchmark", "Measured", "Paper / injected", "Note"],
    );
    let hw = &cfg.hw;
    let tpn = hw.threads_per_node;
    let params = SimParams::from_hw(hw);
    let stream = microbench::stream_sim(hw, tpn, 1 << 22);
    t.row(vec![
        format!("STREAM ({tpn} thr/node)"),
        format!("{:.1} GB/s", stream.bandwidth() / 1e9),
        format!("{:.1} GB/s", hw.w_thread_private * tpn as f64 / 1e9),
        "aggregate node bandwidth".into(),
    ]);
    let pp = microbench::pingpong_sim(hw, 64 << 20, 4);
    t.row(vec![
        "MPI ping-pong (64 MiB)".into(),
        format!("{:.2} GB/s", pp.bandwidth() / 1e9),
        format!("{:.2} GB/s", hw.w_node_remote / 1e9),
        "inter-node bandwidth".into(),
    ]);
    let tau8 = microbench::tau_sim(&params, 8, 100_000);
    t.row(vec![
        "Listing-6 τ (8 thr)".into(),
        format!("{:.2} µs", tau8 * 1e6),
        format!("{:.2} µs", hw.tau * 1e6),
        "individual remote op".into(),
    ]);
    let tau2 = microbench::tau_sim(&params, 2, 100_000);
    t.row(vec![
        "Listing-6 τ (2 thr)".into(),
        format!("{:.2} µs", tau2 * 1e6),
        format!("< {:.2} µs", hw.tau * 1e6),
        "§6.4: fewer communicating threads".into(),
    ]);
    let host = microbench::stream_host(1 << 21);
    t.row(vec![
        "Host STREAM (real)".into(),
        format!("{:.1} GB/s", host.bandwidth() / 1e9),
        "—".into(),
        "roofline anchor for §Perf".into(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_both_rows() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = table1(&cfg, &mut ws);
        assert_eq!(t.rows.len(), 2);
        assert!(t.rows[0][1].contains("6,810,586"));
    }

    #[test]
    fn table2_shape() {
        let cfg = HarnessConfig::test_sized();
        let mut ws = Workspace::new();
        let t = table2(&cfg, &mut ws);
        assert_eq!(t.rows.len(), 4); // naive, v1, 2 paper rows
        // Naive slower than v1 at every thread count.
        for c in 1..6 {
            let naive: f64 = t.rows[0][c].parse().unwrap();
            let v1: f64 = t.rows[1][c].parse().unwrap();
            assert!(naive > v1, "col {c}: naive {naive} v1 {v1}");
        }
    }

    #[test]
    fn table5_has_12_rows() {
        let cfg = HarnessConfig::test_sized();
        let t = table5(&cfg);
        assert_eq!(t.rows.len(), 12);
    }

    fn leading_number(cell: &str) -> f64 {
        cell.split_whitespace()
            .next()
            .and_then(|tok| tok.parse().ok())
            .unwrap_or_else(|| panic!("no leading number in {cell:?}"))
    }

    /// The simulated microbenchmarks must recover whatever `HwParams` were
    /// injected — asserted numerically against `cfg.hw`, not against Abel
    /// string literals (the old `starts_with("75.0")` check silently passed
    /// only because the table hard-coded 16 threads and Abel constants).
    #[test]
    fn microbench_recovers_constants() {
        let mut host_cfg = HarnessConfig::test_sized();
        host_cfg.hw = HwParams {
            w_thread_private: 2.75e9,
            w_node_remote: 13.0e9,
            tau: 0.21e-6,
            cache_line: 128,
            threads_per_node: 6,
            w_node_single: 7.5e9,
            w_pack: 2.75e9,
        };
        host_cfg.hw_label = "injected".into();
        for cfg in [HarnessConfig::test_sized(), host_cfg] {
            let t = microbench_table(&cfg);
            let hw = &cfg.hw;
            // STREAM recovers the aggregate node bandwidth of the *injected*
            // thread count.
            assert!(t.rows[0][0].contains(&format!("{} thr/node", hw.threads_per_node)));
            let stream = leading_number(&t.rows[0][1]) * 1e9;
            let want = hw.w_thread_private * hw.threads_per_node as f64;
            assert!((stream - want).abs() / want < 0.02, "stream {stream} vs {want}");
            assert!((leading_number(&t.rows[0][2]) * 1e9 - want).abs() / want < 0.02);
            // Ping-pong recovers the remote bandwidth.
            let pp = leading_number(&t.rows[1][1]) * 1e9;
            assert!((pp - hw.w_node_remote).abs() / hw.w_node_remote < 0.02, "{pp}");
            // Listing-6 recovers τ at the 8-thread calibration point.
            let tau = leading_number(&t.rows[2][1]) * 1e-6;
            assert!((tau - hw.tau).abs() / hw.tau < 0.02, "{tau} vs {}", hw.tau);
        }
    }
}
