//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §3 maps each experiment id to the function here).
//!
//! All outputs are [`Table`]s: rendered as aligned text for the terminal and
//! saved as CSV under `reports/` when an output directory is configured.
//! Paper reference values are included as columns/rows where the paper
//! printed them, so the "same shape?" comparison is immediate.

mod ablations;
mod baselines;
mod dynamic;
mod figures;
mod planopt;
mod tables;
mod validate;

pub use ablations::{ablation_blocksize, ablation_ordering, ablation_threads_per_node};
pub use baselines::baseline_mpi;
pub use dynamic::{validate_dynamic, DynamicRow};
pub use figures::{figure1, figure2_blocksize, figure2_volumes, plot_figure};
pub use planopt::{validate_planopt, PlanoptRow};
pub use tables::{microbench_table, table1, table2, table3, table4, table5};
pub use validate::{
    model_chosen_depth, model_validation, ValidationPoint, ValidationReport, WorkloadPoint,
    WORKLOAD_LABELS,
};

use crate::engine::Engine;
use crate::machine::HwParams;
use crate::matrix::Ellpack;
use crate::mesh::{Ordering, TestProblem, TetMesh};
use crate::util::fmt::Table;
use std::collections::HashMap;
use std::path::PathBuf;

/// Harness configuration shared by all experiments.
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    /// Problem scale divisor (16 = EXPERIMENTS.md default; 1 = paper scale).
    pub scale_div: usize,
    /// Accounted SpMV iterations (paper: 1000).
    pub iters: usize,
    pub hw: HwParams,
    /// Where `hw` came from (`abel`, `host`, `file:<path>`) — stamped into
    /// table titles and JSON reports so outputs are self-describing.
    pub hw_label: String,
    /// Execution engine for the real data-movement steps some experiments
    /// run alongside the simulated timings (e.g. `baseline-mpi`).
    pub engine: Engine,
    /// Where to save `<name>.txt` / `<name>.csv`; `None` = print only.
    pub out_dir: Option<PathBuf>,
}

impl Default for HarnessConfig {
    fn default() -> Self {
        HarnessConfig {
            scale_div: 16,
            iters: 1000,
            hw: HwParams::abel(),
            hw_label: "abel".to_string(),
            engine: Engine::Sequential,
            out_dir: Some(PathBuf::from("reports")),
        }
    }
}

impl HarnessConfig {
    /// A configuration small enough for unit/integration tests. Runs the
    /// parallel engine so the worker pool is exercised end-to-end by every
    /// harness test.
    pub fn test_sized() -> HarnessConfig {
        HarnessConfig {
            scale_div: 256,
            iters: 10,
            hw: HwParams::abel(),
            hw_label: "abel".to_string(),
            engine: Engine::Parallel,
            out_dir: None,
        }
    }

    /// LLC reuse window scaled with the problem. The mesh's stencil
    /// bandwidth (index span of a row's neighbours) scales as n^(2/3) — a
    /// z-layer of the shell — so the window scales by `scale_div^(2/3)` to
    /// preserve BOTH paper-regime inequalities:
    /// `stencil span < window ≪ n`.
    pub fn cache_window(&self) -> usize {
        scaled_cache_window(self.scale_div)
    }

    /// `hw` rescaled to a topology's threads-per-node (§5.1): the per-thread
    /// bandwidth share depends on how many threads actually run on a node,
    /// so every experiment simulating or predicting a `tpn`-thread node must
    /// consume this, not the raw parameter set. Identity for the Abel
    /// defaults at `tpn = 16`; load-bearing for injected calibrations whose
    /// `threads_per_node` is the host's core count.
    pub fn hw_for_tpn(&self, tpn: usize) -> HwParams {
        self.hw.with_threads_per_node(tpn)
    }
}

/// Caches meshes and matrices across experiments in one CLI invocation
/// (TP3 at 1/16 scale is ~1.6 M tets; we build it once).
#[derive(Default)]
pub struct Workspace {
    meshes: HashMap<(TestProblem, usize, &'static str), TetMesh>,
    matrices: HashMap<(TestProblem, usize, &'static str), Ellpack>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    pub fn mesh(&mut self, tp: TestProblem, scale_div: usize, ordering: Ordering) -> &TetMesh {
        self.meshes
            .entry((tp, scale_div, ordering.name()))
            .or_insert_with(|| ordering.apply(&tp.generate(scale_div)))
    }

    pub fn matrix(&mut self, tp: TestProblem, scale_div: usize, ordering: Ordering) -> Ellpack {
        if let Some(m) = self.matrices.get(&(tp, scale_div, ordering.name())) {
            return m.clone();
        }
        let mesh = self.mesh(tp, scale_div, ordering).clone();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        self.matrices.insert((tp, scale_div, ordering.name()), m.clone());
        m
    }
}

/// The scale-adjusted LLC reuse window (see [`HarnessConfig::cache_window`]).
pub fn scaled_cache_window(scale_div: usize) -> usize {
    let f = (scale_div as f64).powf(2.0 / 3.0);
    ((crate::sim::DEFAULT_CACHE_WINDOW as f64 / f) as usize).max(64)
}

/// Print a table and persist it (txt + csv) if an output dir is set.
pub fn emit(cfg: &HarnessConfig, name: &str, table: &Table) {
    println!("{}", table.render());
    if let Some(dir) = &cfg.out_dir {
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("warning: cannot create {}: {e}", dir.display());
            return;
        }
        let txt = dir.join(format!("{name}.txt"));
        let csv = dir.join(format!("{name}.csv"));
        let _ = std::fs::write(&txt, table.render());
        let _ = std::fs::write(&csv, table.to_csv());
        if name.starts_with("figure") {
            let _ = std::fs::write(
                dir.join(format!("{name}.plot.txt")),
                figures::plot_figure(table, 32),
            );
        }
        println!("[saved {} and {}]", txt.display(), csv.display());
    }
}

/// Format seconds the way the paper's tables do (plain seconds, 2 decimals).
pub(crate) fn s2(t: f64) -> String {
    if t >= 1000.0 {
        format!("{t:.0}")
    } else if t >= 0.01 {
        format!("{t:.2}")
    } else {
        format!("{t:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_caches() {
        let mut ws = Workspace::new();
        let a = ws.mesh(TestProblem::Tp1, 2048, Ordering::Natural).n;
        let b = ws.mesh(TestProblem::Tp1, 2048, Ordering::Natural).n;
        assert_eq!(a, b);
        assert_eq!(ws.meshes.len(), 1);
        let m = ws.matrix(TestProblem::Tp1, 2048, Ordering::Natural);
        assert_eq!(m.n, a);
    }

    #[test]
    fn s2_formats() {
        assert_eq!(s2(28.804), "28.80");
        assert_eq!(s2(1882.01), "1882");
        assert_eq!(s2(0.0042), "0.0042");
    }
}
