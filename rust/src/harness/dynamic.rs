//! Dynamic-pattern validation (`repro validate --dynamic`): mdlite's
//! measured per-step cost at several rebuild periods against the
//! rebuild-amortization model `T_total ≈ R·T_recompile(|delta|) +
//! steps·T_step`, emitting `BENCH_dynamic.json`.
//!
//! The methodology mirrors [`validate_planopt`](super::validate_planopt):
//! calibrate, measure, predict, ratio, budget — and the JSON artifact is
//! written *before* the budget gate so a failing run still leaves evidence
//! behind. Calibration is anchored on the workload itself: a from-scratch
//! compile and a K-step [`PlanDelta`](crate::comm::PlanDelta) are timed
//! through the [`mdlite`] hooks, and the per-step compute term comes from
//! the static row (one rebuild over the whole run), so the K ∈ {16, 64}
//! rows isolate exactly the recompile-amortization delta the
//! [`RebuildModel`] claims to predict.

use crate::engine::Engine;
use crate::mdlite::{self, Lifecycle, MdConfig};
use crate::model::RebuildModel;
use crate::util::json::Value;
use anyhow::{anyhow, ensure};
use std::time::Instant;

/// One rebuild-period row: measured incremental-lifecycle seconds per step
/// against the rebuild model's prediction.
#[derive(Debug, Clone, Copy)]
pub struct DynamicRow {
    pub label: &'static str,
    /// Rebuild period K (the static row uses K = steps: one generation-0
    /// compile, never rebuilt).
    pub rebuild_every: usize,
    /// Plan generations the run actually compiled.
    pub generations: u64,
    /// Dirty (receiver, sender) pairs across all incremental rebuilds.
    pub dirty_pairs: usize,
    /// Median measured seconds per step.
    pub measured: f64,
    /// Model-predicted seconds per step.
    pub predicted: f64,
}

impl DynamicRow {
    pub fn ratio(&self) -> f64 {
        self.measured / self.predicted
    }
}

/// The timing-sized workload: large enough that a per-step median is
/// stable, long enough (steps > 64) that the K = 64 row rebuilds at least
/// once beyond generation 0.
fn bench_config(quick: bool) -> MdConfig {
    MdConfig {
        cells_x: 48,
        cells_y: 48,
        threads: 4,
        particles: if quick { 256 } else { 1024 },
        steps: if quick { 96 } else { 192 },
        rebuild_every: 16,
        seed: 0xD7A1,
    }
}

/// Median of `samples` timed evaluations of `f`, after one warmup call.
fn median_seconds(samples: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// Calibrate the rebuild model's compile-cost terms on the workload itself
/// and time the incremental lifecycle at each rebuild period against the
/// model. Gates every row's measured/predicted ratio on `budget`.
pub fn validate_dynamic(quick: bool, budget: f64) -> anyhow::Result<Vec<DynamicRow>> {
    ensure!(budget > 1.0, "need a ratio budget > 1");
    let cfg = bench_config(quick);
    let steps = cfg.steps;
    let samples = if quick { 3 } else { 5 };
    let err = |e: String| anyhow!(e);

    // Bitwise equivalence first: a mistimed model is a finding, a wrong
    // field is a bug.
    let oracle = mdlite::run(&cfg, Engine::Sequential, Lifecycle::FullRecompile).map_err(err)?;
    let incr = mdlite::run(&cfg, Engine::Sequential, Lifecycle::Incremental).map_err(err)?;
    ensure!(
        oracle.checksum() == incr.checksum(),
        "incremental lifecycle diverged bitwise from the full-recompile oracle"
    );

    // Calibrate the compile-cost terms through the mdlite hooks: a
    // from-scratch compile, and the construction + application of one
    // K-step delta.
    let calib_k = cfg.rebuild_every;
    let base = mdlite::plan_at(&cfg, 0).map_err(err)?;
    let delta = mdlite::delta_between(&cfg, 0, calib_k).map_err(err)?;
    let t_full = median_seconds(samples, || {
        let _ = mdlite::plan_at(&cfg, 0).unwrap();
    });
    let t_build = median_seconds(samples, || {
        let _ = mdlite::delta_between(&cfg, 0, calib_k).unwrap();
    });
    let t_apply = median_seconds(samples, || {
        let _ = base.apply_delta(&delta).unwrap();
    });
    let dirty = delta.dirty_pairs().max(1);

    // Measure the rows: the static anchor (K = steps, one generation-0
    // compile) and the two dynamic periods the CI tracks. Sequential
    // engine, as the other calibration-grade harness rows use.
    let periods: [(&'static str, usize); 3] =
        [("mdlite-static", steps), ("mdlite-k64", 64), ("mdlite-k16", 16)];
    let mut measured = Vec::with_capacity(periods.len());
    for &(label, k) in &periods {
        let mut run_cfg = cfg;
        run_cfg.rebuild_every = k;
        let stats =
            mdlite::run(&run_cfg, Engine::Sequential, Lifecycle::Incremental).map_err(err)?;
        let per_step = median_seconds(samples, || {
            let _ = mdlite::run(&run_cfg, Engine::Sequential, Lifecycle::Incremental).unwrap();
        }) / steps as f64;
        measured.push((label, k, stats, per_step));
    }

    // Anchor the per-step compute term on the static row: everything it
    // spends beyond its single modeled rebuild is stepping, so the dynamic
    // rows isolate the recompile-amortization delta. Staleness is
    // volume-neutral in mdlite at these densities (a stale plan gathers a
    // near-identical halo), so the penalty term is zero.
    let mut model = RebuildModel {
        t_step: 0.0,
        t_full,
        t_rebuild_fixed: t_build,
        t_delta_pair: t_apply / dirty as f64,
        drift_pairs_per_step: dirty as f64 / calib_k as f64,
        max_pairs: measured[0].2.plan_pairs.max(1) as f64,
        stale_step_penalty: 0.0,
    };
    let static_per_step = measured[0].3;
    let static_recompile = model.recompile_cost(steps, true) / steps as f64;
    model.t_step = (static_per_step - static_recompile).max(static_per_step * 0.1);

    let mut rows = Vec::with_capacity(measured.len());
    for &(label, k, ref stats, per_step) in &measured {
        let predicted = model.predict(steps, k, true).total_seconds / steps as f64;
        rows.push(DynamicRow {
            label,
            rebuild_every: k,
            generations: stats.generations,
            dirty_pairs: stats.dirty_pairs,
            measured: per_step,
            predicted,
        });
    }

    println!(
        "{:<14} {:>5} {:>5} {:>6} {:>12} {:>12} {:>7}",
        "row", "K", "gens", "dirty", "meas s/step", "pred s/step", "ratio"
    );
    let mut ok = true;
    for row in &rows {
        let ratio = row.ratio();
        let in_budget = ratio.is_finite() && ratio <= budget && ratio >= 1.0 / budget;
        ok &= in_budget;
        println!(
            "{:<14} {:>5} {:>5} {:>6} {:>12.3e} {:>12.3e} {:>7.2}{}",
            row.label,
            row.rebuild_every,
            row.generations,
            row.dirty_pairs,
            row.measured,
            row.predicted,
            ratio,
            if in_budget { "" } else { "  <-- outside budget" }
        );
    }
    let (k_star, best) = model.choose_rebuild_period(steps, true);
    println!(
        "model-chosen rebuild period: K = {k_star} ({:.3e} s/step predicted)",
        best.total_seconds / steps as f64
    );

    let mut arr = Vec::with_capacity(rows.len());
    for row in &rows {
        let mut o = Value::obj();
        o.set("row", Value::Str(row.label.into()));
        o.set("rebuild_every", Value::Num(row.rebuild_every as f64));
        o.set("generations", Value::Num(row.generations as f64));
        o.set("dirty_pairs", Value::Num(row.dirty_pairs as f64));
        o.set("measured_s_per_step", Value::Num(row.measured));
        o.set("predicted_s_per_step", Value::Num(row.predicted));
        o.set("ratio", Value::Num(row.ratio()));
        arr.push(o);
    }
    let mut calibration = Value::obj();
    calibration.set("t_step_s", Value::Num(model.t_step));
    calibration.set("t_full_s", Value::Num(model.t_full));
    calibration.set("t_rebuild_fixed_s", Value::Num(model.t_rebuild_fixed));
    calibration.set("t_delta_pair_s", Value::Num(model.t_delta_pair));
    calibration.set("drift_pairs_per_step", Value::Num(model.drift_pairs_per_step));
    calibration.set("max_pairs", Value::Num(model.max_pairs));
    let mut root = Value::obj();
    root.set("bench", Value::Str("validate/dynamic".into()));
    root.set("cells_x", Value::Num(cfg.cells_x as f64));
    root.set("cells_y", Value::Num(cfg.cells_y as f64));
    root.set("threads", Value::Num(cfg.threads as f64));
    root.set("particles", Value::Num(cfg.particles as f64));
    root.set("steps", Value::Num(steps as f64));
    root.set("samples", Value::Num(samples as f64));
    root.set("budget", Value::Num(budget));
    root.set("chosen_rebuild_period", Value::Num(k_star as f64));
    root.set("calibration", calibration);
    root.set("rows", Value::Arr(arr));
    crate::benchlib::save_bench_json(
        "BENCH_dynamic.json",
        "rebuild amortization validation",
        &root,
    );

    ensure!(
        ok,
        "dynamic-pattern validation failed: at least one measured/predicted \
         ratio outside {budget:.0}x"
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_dynamic_quick_passes() {
        let rows = validate_dynamic(true, 1e9).expect("dynamic validation");
        assert_eq!(rows.len(), 3);
        let k64 = rows.iter().find(|r| r.label == "mdlite-k64").unwrap();
        assert!(k64.generations >= 2, "K = 64 must rebuild beyond generation 0");
        for row in &rows {
            assert!(row.measured > 0.0 && row.predicted > 0.0, "{}", row.label);
            assert!(row.ratio().is_finite(), "{}", row.label);
        }
        let _ = std::fs::remove_file("BENCH_dynamic.json");
    }

    #[test]
    fn validate_dynamic_rejects_bad_budget() {
        assert!(validate_dynamic(true, 1.0).is_err());
    }
}
