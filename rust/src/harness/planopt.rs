//! Plan-optimizer validation (`repro validate --optimize`): the measured
//! raw-vs-optimized per-step win for every workload, checked against the
//! model's prediction from the condensed message count and volume alone.
//!
//! The methodology mirrors [`validate_transport`]: measure, predict, ratio,
//! geomean, budget — and the `BENCH_planopt.json` artifact is written
//! *before* the budget gate so a failing run still leaves evidence behind.
//! The prediction is anchored the way the paper anchors its UPCv3 columns:
//! the computation term is whatever the *optimized* run spends beyond its
//! modeled communication, so the speedup ratio isolates the communication
//! delta that [`PlanOptimizer`] is responsible for.
//!
//! [`validate_transport`]: crate::transport::validate_transport
//! [`PlanOptimizer`]: crate::comm::PlanOptimizer

use crate::comm::{Analysis, PlanStats};
use crate::engine::{Engine, SpmvEngine};
use crate::heat2d::Heat2dSolver;
use crate::machine::{HwParams, TransportModel};
use crate::matrix::Ellpack;
use crate::model::{comm_seconds_on, predict_planopt_speedup};
use crate::pgas::Topology;
use crate::spmv::{SpmvState, Variant};
use crate::stencil3d::Stencil3dSolver;
use crate::transport::{run_reference_mode, PlanMode, Proto, WorkloadSpec, WORKLOADS};
use crate::util::json::Value;
use crate::util::Rng;
use anyhow::ensure;
use std::time::Instant;

/// One workload's raw-vs-optimized comparison: the plan statistics on both
/// sides, the measured per-step medians, and the modeled speedup.
#[derive(Debug, Clone, Copy)]
pub struct PlanoptRow {
    pub workload: &'static str,
    pub raw: PlanStats,
    pub optimized: PlanStats,
    /// Median per-step seconds running the raw (per-element) plan.
    pub t_raw: f64,
    /// Median per-step seconds running the optimized plan.
    pub t_opt: f64,
    pub speedup_measured: f64,
    pub speedup_predicted: f64,
}

impl PlanoptRow {
    /// Measured-over-predicted speedup ratio (1.0 = the model nailed it).
    pub fn ratio(&self) -> f64 {
        self.speedup_measured / self.speedup_predicted
    }
}

/// Measure every workload with its raw and optimized plans, verify the two
/// produce bitwise-identical fields under every protocol, and compare the
/// measured speedup against [`predict_planopt_speedup`] within `budget`.
///
/// [`predict_planopt_speedup`]: crate::model::predict_planopt_speedup
pub fn validate_planopt(
    procs: usize,
    steps: u64,
    quick: bool,
    budget: f64,
) -> anyhow::Result<Vec<PlanoptRow>> {
    ensure!(procs >= 2, "plan-optimizer validation needs at least 2 ranks");
    ensure!(steps >= 1 && budget > 1.0, "need steps >= 1 and budget > 1");
    let samples = if quick { 7 } else { 21 };
    let hw = HwParams::abel();
    let tm = TransportModel::inproc();

    let mut rows = Vec::with_capacity(WORKLOADS.len());
    for name in WORKLOADS {
        let spec = WorkloadSpec::for_name(name, procs).unwrap();
        equivalence_check(&spec, name, steps)?;
        let raw = PlanStats::of(&spec.plan_with(PlanMode::Raw));
        let optimized = PlanStats::of(&spec.plan_with(PlanMode::Optimized));
        ensure!(
            optimized.improves_on(&raw),
            "{name}: optimized plan does not improve on the raw plan \
             ({raw:?} -> {optimized:?})"
        );
        let t_raw = measured_step_seconds(&spec, PlanMode::Raw, samples);
        let t_opt = measured_step_seconds(&spec, PlanMode::Optimized, samples);
        // Anchor the computation term on the optimized run: everything it
        // spends beyond its own modeled communication is computation, so
        // the predicted speedup comes from the message/volume delta alone.
        let t_comp = (t_opt - comm_seconds_on(tm, &hw, &optimized)).max(0.0);
        let pred = predict_planopt_speedup(tm, &hw, t_comp, &raw, &optimized);
        rows.push(PlanoptRow {
            workload: name,
            raw,
            optimized,
            t_raw,
            t_opt,
            speedup_measured: t_raw / t_opt,
            speedup_predicted: pred.speedup,
        });
    }

    println!(
        "{:<9} {:>13} {:>17} {:>13} {:>10} {:>10} {:>7}",
        "workload", "msgs raw>opt", "bytes raw>opt", "blocks raw>opt", "meas spdup", "pred spdup", "ratio"
    );
    let mut ok = true;
    for row in &rows {
        let ratio = row.ratio();
        let in_budget = ratio.is_finite() && ratio <= budget && ratio >= 1.0 / budget;
        ok &= in_budget;
        println!(
            "{:<9} {:>6}>{:<6} {:>8}>{:<8} {:>6}>{:<7} {:>10.2} {:>10.2} {:>7.2}{}",
            row.workload,
            row.raw.messages,
            row.optimized.messages,
            row.raw.payload_bytes,
            row.optimized.payload_bytes,
            row.raw.blocks,
            row.optimized.blocks,
            row.speedup_measured,
            row.speedup_predicted,
            ratio,
            if in_budget { "" } else { "  <-- outside budget" }
        );
    }
    let sum_ln = rows.iter().map(|r| r.ratio().abs().max(1e-300).ln()).sum::<f64>();
    let geomean = (sum_ln / rows.len() as f64).exp();
    println!("geomean measured/predicted speedup ratio: {geomean:.2} (budget {budget:.0}x)");

    let mut arr = Vec::with_capacity(rows.len());
    for row in &rows {
        let mut o = Value::obj();
        o.set("workload", Value::Str(row.workload.into()));
        o.set("raw", row.raw.to_json());
        o.set("optimized", row.optimized.to_json());
        o.set("t_raw_s", Value::Num(row.t_raw));
        o.set("t_opt_s", Value::Num(row.t_opt));
        o.set("speedup_measured", Value::Num(row.speedup_measured));
        o.set("speedup_predicted", Value::Num(row.speedup_predicted));
        o.set("ratio", Value::Num(row.ratio()));
        arr.push(o);
    }
    let mut root = Value::obj();
    root.set("bench", Value::Str("plan_optimize".into()));
    root.set("procs", Value::Num(procs as f64));
    root.set("steps", Value::Num(steps as f64));
    root.set("samples", Value::Num(samples as f64));
    root.set("budget", Value::Num(budget));
    root.set("geomean_ratio", Value::Num(geomean));
    root.set("rows", Value::Arr(arr));
    crate::benchlib::save_bench_json("BENCH_planopt.json", "plan optimizer validation", &root);

    ensure!(
        ok && geomean.is_finite(),
        "plan-optimizer validation failed: at least one measured/predicted \
         speedup ratio outside {budget:.0}x"
    );
    Ok(rows)
}

/// Fields must be bitwise identical across the raw, compiled, and optimized
/// plans under every protocol — the optimizer changes message granularity,
/// never values.
fn equivalence_check(spec: &WorkloadSpec, name: &str, steps: u64) -> anyhow::Result<()> {
    for proto in Proto::ALL {
        let compiled = run_reference_mode(spec, proto, steps, PlanMode::Compiled);
        for mode in [PlanMode::Raw, PlanMode::Optimized] {
            let world = run_reference_mode(spec, proto, steps, mode);
            ensure!(
                field_bits(&world.fields) == field_bits(&compiled.fields),
                "{name}/{}: {} plan diverged bitwise from the compiled plan",
                proto.name(),
                mode.name()
            );
        }
    }
    Ok(())
}

fn field_bits(fields: &[Vec<f64>]) -> Vec<Vec<u64>> {
    fields.iter().map(|f| f.iter().map(|v| v.to_bits()).collect()).collect()
}

/// Median per-step seconds for `spec` running `mode`'s plan on the
/// sequential in-process engine (1 warmup step, then `samples` timed).
fn measured_step_seconds(spec: &WorkloadSpec, mode: PlanMode, samples: usize) -> f64 {
    let plan = spec.plan_with(mode);
    match *spec {
        WorkloadSpec::Heat { grid, seed } => {
            let global = seeded_field(grid.m_glob * grid.n_glob, seed);
            let strided = plan.as_strided().expect("heat runs a strided plan").clone();
            let mut solver = Heat2dSolver::with_plan(grid, &global, strided);
            median_step_seconds(|| solver.step_with(Engine::Sequential), samples)
        }
        WorkloadSpec::Stencil { grid, seed } => {
            let global = seeded_field(grid.p_glob * grid.m_glob * grid.n_glob, seed);
            let strided = plan.as_strided().expect("stencil runs a strided plan").clone();
            let mut solver = Stencil3dSolver::with_plan(grid, &global, strided);
            median_step_seconds(|| solver.step_with(Engine::Sequential), samples)
        }
        WorkloadSpec::Spmv(p) => {
            let m = Ellpack::random(p.n, p.r_nz, p.mat_seed);
            let x0 = m.initial_vector(p.x_seed);
            let mut state = SpmvState::new(&m, p.block, p.procs, &x0);
            let mut analysis = Analysis::build(
                &m.j,
                m.r_nz,
                state.layout,
                Topology::single_node(p.procs),
                usize::MAX,
            );
            analysis.plan = plan.as_gather().expect("spmv runs a gather plan").clone();
            let mut engine = SpmvEngine::new(Engine::Sequential);
            median_step_seconds(
                || {
                    engine.run(Variant::V3, &mut state, Some(&analysis));
                    state.swap_xy();
                },
                samples,
            )
        }
    }
}

fn median_step_seconds(mut step: impl FnMut(), samples: usize) -> f64 {
    step(); // warmup
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        step();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// The launch module's deterministic initial field, reproduced here so the
/// timed solvers start from the same data the reference worlds use.
fn seeded_field(len: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.f64_in(0.0, 100.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_planopt_quick_passes_and_improves() {
        let rows = validate_planopt(2, 2, true, 1e9).expect("planopt validation");
        assert_eq!(rows.len(), WORKLOADS.len());
        for row in &rows {
            assert!(row.optimized.improves_on(&row.raw), "{}", row.workload);
            assert!(row.t_raw > 0.0 && row.t_opt > 0.0, "{}", row.workload);
            assert!(row.speedup_predicted >= 1.0, "{}", row.workload);
            assert!(row.ratio().is_finite(), "{}", row.workload);
        }
        let spmv = rows.iter().find(|r| r.workload == "spmv").unwrap();
        assert!(
            spmv.optimized.values < spmv.raw.values,
            "condensing must shrink the spmv gather volume"
        );
        let _ = std::fs::remove_file("BENCH_planopt.json");
    }

    #[test]
    fn validate_planopt_rejects_bad_arguments() {
        assert!(validate_planopt(1, 2, true, 25.0).is_err());
        assert!(validate_planopt(2, 0, true, 25.0).is_err());
        assert!(validate_planopt(2, 2, true, 1.0).is_err());
    }
}
