//! The condensing/consolidation performance model.
//!
//! The paper's enhancement-three argument (§4.3/§5.2.5) is that condensing
//! and consolidating changes *only* the communication term: computation is
//! untouched, so the whole win must be predictable from the plan-size
//! deltas that [`PlanStats`](crate::comm::PlanStats) reports. The per-step
//! communication time for a compiled plan is the §5 message model applied
//! to the critical-path thread:
//!
//! ```text
//! T_comm = M_max · t_msg + V_max · L/W_private + B_total / W_eff
//! ```
//!
//! where `M_max`/`V_max` are the busiest receiver's message and value
//! counts (threads exchange concurrently, so the slowest receiver binds the
//! step), `B_total` the payload bytes crossing the shared wire, and `t_msg`
//! the per-message fixed cost: τ_eff on a real transport
//! ([`TransportModel::apply`]'s substituted latency), but only a cache-line
//! touch `L/W_private` for the in-process world, where a "message" is a
//! pack/unpack loop iteration and no syscall or wire round-trip exists —
//! charging τ per in-process message would over-predict the raw plans by
//! orders of magnitude.
//!
//! The optimized-vs-raw step-time ratio then follows from the stats alone:
//! `speedup = (T_comp + T_comm(before)) / (T_comp + T_comm(after))` with
//! the computation term measured once (it cancels out of the comparison —
//! exactly the paper's "the model predicts the enhancement win from the
//! communication volume it removes").

use crate::comm::PlanStats;
use crate::machine::{HwParams, TransportModel};

/// Modeled before/after communication times and the step-speedup they
/// imply, for one workload under one transport.
#[derive(Debug, Clone, Copy)]
pub struct PlanoptPrediction {
    /// Per-step communication seconds for the raw plan.
    pub t_comm_raw: f64,
    /// Per-step communication seconds for the optimized plan.
    pub t_comm_opt: f64,
    /// The computation anchor both step times share.
    pub t_comp: f64,
    /// `(t_comp + t_comm_raw) / (t_comp + t_comm_opt)`.
    pub speedup: f64,
}

/// Per-step communication seconds for a plan of the given size on the
/// given transport (the `T_comm` formula above).
pub fn comm_seconds_on(tm: TransportModel, hw: &HwParams, stats: &PlanStats) -> f64 {
    let eff = tm.apply(hw);
    let t_msg = match tm {
        TransportModel::Inproc => hw.t_indv_local(),
        TransportModel::Socket { .. } => eff.tau,
    };
    stats.max_thread_messages as f64 * t_msg
        + stats.max_thread_values as f64 * hw.t_indv_local()
        + stats.payload_bytes as f64 / eff.w_node_remote
}

/// Predict the optimized-over-raw step speedup from the two stats reports
/// and a measured computation anchor (seconds of non-communication work per
/// step, identical in both worlds by construction).
pub fn predict_planopt_speedup(
    tm: TransportModel,
    hw: &HwParams,
    t_comp: f64,
    before: &PlanStats,
    after: &PlanStats,
) -> PlanoptPrediction {
    let t_comm_raw = comm_seconds_on(tm, hw, before);
    let t_comm_opt = comm_seconds_on(tm, hw, after);
    PlanoptPrediction {
        t_comm_raw,
        t_comm_opt,
        t_comp,
        speedup: (t_comp + t_comm_raw) / (t_comp + t_comm_opt),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(messages: usize, values: usize) -> PlanStats {
        PlanStats {
            messages,
            values,
            payload_bytes: (values * 8) as u64,
            blocks: values,
            index_arena_bytes: 8 * values,
            max_thread_messages: messages,
            max_thread_values: values,
        }
    }

    #[test]
    fn comm_time_is_monotone_in_messages_and_volume() {
        let hw = HwParams::abel();
        for tm in [TransportModel::inproc(), TransportModel::socket(30e-6, 1.2e9)] {
            let base = comm_seconds_on(tm, &hw, &stats(100, 1000));
            assert!(comm_seconds_on(tm, &hw, &stats(200, 1000)) > base);
            assert!(comm_seconds_on(tm, &hw, &stats(100, 2000)) > base);
            assert!(comm_seconds_on(tm, &hw, &stats(10, 100)) < base);
        }
    }

    #[test]
    fn socket_charges_latency_per_message_inproc_does_not() {
        // 1000 extra messages at equal volume: a wire transport pays
        // ~1000·τ more, the in-process world only ~1000 cache lines.
        let hw = HwParams::abel();
        let sock = TransportModel::socket(30e-6, 1.2e9);
        let d_sock = comm_seconds_on(sock, &hw, &stats(1100, 1000))
            - comm_seconds_on(sock, &hw, &stats(100, 1000));
        let d_in = comm_seconds_on(TransportModel::inproc(), &hw, &stats(1100, 1000))
            - comm_seconds_on(TransportModel::inproc(), &hw, &stats(100, 1000));
        assert!((d_sock - 1000.0 * 30e-6).abs() / d_sock < 1e-6);
        assert!(d_in < d_sock / 100.0);
    }

    #[test]
    fn speedup_comes_from_the_stats_delta_alone() {
        let hw = HwParams::abel();
        let tm = TransportModel::socket(30e-6, 1.2e9);
        let raw = stats(4000, 4000);
        let opt = stats(40, 1000);
        let p = predict_planopt_speedup(tm, &hw, 1e-3, &raw, &opt);
        assert!(p.speedup > 1.0, "condensing must predict a win: {p:?}");
        assert!(p.t_comm_opt < p.t_comm_raw);
        // Equal stats ⇒ no predicted win, whatever the compute anchor.
        let same = predict_planopt_speedup(tm, &hw, 1e-3, &raw, &raw);
        assert!((same.speedup - 1.0).abs() < 1e-12);
        // A larger compute anchor dilutes the speedup toward 1.
        let diluted = predict_planopt_speedup(tm, &hw, 1.0, &raw, &opt);
        assert!(diluted.speedup < p.speedup && diluted.speedup >= 1.0);
    }
}
