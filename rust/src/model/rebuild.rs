//! Rebuild-amortization model for dynamic communication patterns.
//!
//! A dynamic-pattern workload (mdlite, MD neighbor lists, PIC) recompiles
//! its exchange plan every K steps. The run cost decomposes as
//!
//! ```text
//! T_total ≈ R · T_recompile(|delta|) + steps · T_step(K)
//! ```
//!
//! with `R = ⌈steps / K⌉` rebuilds. The two halves pull K in opposite
//! directions:
//!
//! * **Recompile amortization** — each rebuild costs either a full compile
//!   `t_full` or an incremental patch `t_delta_pair · |dirty pairs|`. The
//!   dirty-pair count grows with K (the pattern drifts further between
//!   rebuilds, `≈ drift_pairs_per_step · K`) but is capped at the plan's
//!   live pair count, where the incremental path degenerates to a full
//!   compile. Larger K → fewer, bigger rebuilds.
//! * **Staleness** — between rebuilds the plan lags the pattern; steps run
//!   with an increasingly stale halo. The j-th step after a rebuild pays
//!   `j · stale_step_penalty` (extra gather volume, wasted or missing
//!   prefetches), averaging `(K−1)/2` staleness steps. Larger K → more
//!   staleness.
//!
//! [`RebuildModel::choose_rebuild_period`] scans K and returns the argmin,
//! the dynamic-pattern analogue of
//! [`choose_depth`](super::choose_depth) for the pipeline tier.

/// Cost parameters of the versioned plan lifecycle, in seconds. Calibrate
/// `t_full` / `t_delta_pair` from `benches/plan_optimize.rs` and the step
/// and drift terms from the workload's own counters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RebuildModel {
    /// Seconds per simulation step (compute + exchange), staleness aside.
    pub t_step: f64,
    /// Seconds for a from-scratch plan compile.
    pub t_full: f64,
    /// Fixed seconds per rebuild regardless of size: delta construction,
    /// fingerprint chain, transport reshape, wire shipping. This is what
    /// makes rebuild-every-step expensive even with tiny deltas — without
    /// it the incremental cost `R · (c·K) = steps · c` is K-independent
    /// and staleness would always drive K to 1.
    pub t_rebuild_fixed: f64,
    /// Seconds per dirty (receiver, sender) pair for an incremental patch.
    pub t_delta_pair: f64,
    /// Pattern drift rate: dirty pairs accumulated per step between
    /// rebuilds.
    pub drift_pairs_per_step: f64,
    /// Live (receiver, sender) pairs in the plan — caps the dirty count.
    pub max_pairs: f64,
    /// Extra seconds per step per step-of-staleness of the plan.
    pub stale_step_penalty: f64,
}

/// One (K, lifecycle) evaluation of the rebuild model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RebuildPrediction {
    /// The rebuild period evaluated.
    pub period: usize,
    /// `⌈steps / K⌉`.
    pub rebuilds: usize,
    /// Seconds per rebuild: the fixed overhead plus
    /// `min(t_full, t_delta_pair · dirty(K))` when incremental, or plus
    /// `t_full` otherwise.
    pub t_recompile: f64,
    /// Total recompile seconds (`R · T_recompile`).
    pub recompile_seconds: f64,
    /// Total staleness seconds.
    pub stale_seconds: f64,
    /// `R · T_recompile + steps · T_step + staleness`.
    pub total_seconds: f64,
}

impl RebuildModel {
    /// Expected dirty pairs after `k` steps of drift, capped by the live
    /// pair count.
    pub fn dirty_pairs(&self, k: usize) -> f64 {
        (self.drift_pairs_per_step * k as f64).min(self.max_pairs)
    }

    /// Seconds for one rebuild at period `k`. The incremental path never
    /// costs more than a full compile — at high drift it degenerates to
    /// one, which is exactly how the runtime would fall back.
    pub fn recompile_cost(&self, k: usize, incremental: bool) -> f64 {
        let variable = if incremental {
            (self.t_delta_pair * self.dirty_pairs(k)).min(self.t_full)
        } else {
            self.t_full
        };
        self.t_rebuild_fixed + variable
    }

    /// Evaluate `T_total ≈ R · T_recompile(|delta|) + steps · T_step` plus
    /// the staleness term for a run of `steps` at rebuild period `k`.
    pub fn predict(&self, steps: usize, k: usize, incremental: bool) -> RebuildPrediction {
        assert!(k >= 1, "rebuild period must be positive");
        assert!(steps >= 1, "model a run of at least one step");
        let rebuilds = steps.div_ceil(k);
        let t_recompile = self.recompile_cost(k, incremental);
        let recompile_seconds = rebuilds as f64 * t_recompile;
        // Exact staleness sum: full cycles pay 0 + 1 + … + (k−1); the
        // trailing partial cycle pays its own triangular sum.
        let full_cycles = steps / k;
        let tail = steps % k;
        let tri = |m: usize| (m * m.saturating_sub(1) / 2) as f64;
        let stale_steps = full_cycles as f64 * tri(k) + tri(tail);
        let stale_seconds = stale_steps * self.stale_step_penalty;
        let total_seconds = recompile_seconds + steps as f64 * self.t_step + stale_seconds;
        RebuildPrediction {
            period: k,
            rebuilds,
            t_recompile,
            recompile_seconds,
            stale_seconds,
            total_seconds,
        }
    }

    /// Scan `K ∈ [1, steps]` and return the period minimizing predicted
    /// total time (ties break toward the smaller K, i.e. the fresher plan).
    pub fn choose_rebuild_period(
        &self,
        steps: usize,
        incremental: bool,
    ) -> (usize, RebuildPrediction) {
        assert!(steps >= 1);
        let mut best = self.predict(steps, 1, incremental);
        for k in 2..=steps {
            let p = self.predict(steps, k, incremental);
            if p.total_seconds < best.total_seconds {
                best = p;
            }
        }
        (best.period, best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> RebuildModel {
        RebuildModel {
            t_step: 1.0e-3,
            t_full: 5.0e-2,
            t_rebuild_fixed: 2.0e-3,
            t_delta_pair: 1.0e-4,
            drift_pairs_per_step: 2.0,
            max_pairs: 400.0,
            stale_step_penalty: 2.0e-4,
        }
    }

    #[test]
    fn incremental_rebuild_never_exceeds_full() {
        let m = model();
        for k in [1usize, 4, 16, 64, 1000] {
            assert!(m.recompile_cost(k, true) <= m.recompile_cost(k, false) + 1e-15);
        }
        // At huge K the dirty count caps and the two coincide.
        assert_eq!(m.recompile_cost(10_000, true), m.t_rebuild_fixed + m.t_full);
    }

    #[test]
    fn amortization_formula_is_exact_for_divisible_runs() {
        let m = model();
        let p = m.predict(100, 10, false);
        assert_eq!(p.rebuilds, 10);
        assert!((p.recompile_seconds - 10.0 * m.t_full).abs() < 1e-12);
        // 10 cycles × (0+1+…+9) = 450 stale steps.
        assert!((p.stale_seconds - 450.0 * m.stale_step_penalty).abs() < 1e-12);
    }

    #[test]
    fn chosen_period_is_an_interior_optimum() {
        let m = model();
        let (k, best) = m.choose_rebuild_period(200, true);
        assert!(k > 1, "rebuild-every-step should not win at these costs");
        assert!(k < 200, "never rebuilding should not win either");
        let down = m.predict(200, k - 1, true);
        let up = m.predict(200, k + 1, true);
        assert!(best.total_seconds <= down.total_seconds);
        assert!(best.total_seconds <= up.total_seconds);
    }

    #[test]
    fn incremental_lifecycle_prefers_shorter_periods() {
        // Cheap deltas make frequent rebuilds affordable; the full-compile
        // lifecycle has to amortize a big fixed cost over longer periods.
        let m = model();
        let (k_incr, p_incr) = m.choose_rebuild_period(200, true);
        let (k_full, p_full) = m.choose_rebuild_period(200, false);
        assert!(k_incr <= k_full, "incremental {k_incr} vs full {k_full}");
        assert!(p_incr.total_seconds <= p_full.total_seconds);
    }
}
