//! 3D 7-point-stencil performance model — the §8.2 methodology
//! (eqs. (19)–(22)) generalized to the third workload.
//!
//! Same decomposition as the heat-2D model: per-thread pack/unpack time for
//! the strided faces (eq. (19)), per-node memget time with local transfers
//! concurrent and remote ones serialized on the NIC (eq. (20)), halo = max
//! over nodes of pack + memget + unpack (eq. (21)), compute from streamed
//! interior traffic (eq. (22)).
//!
//! The only 3D-specific choice is *which* faces pay pack time: x/y-faces
//! are row-chunked (contiguous runs of `n−2` doubles — executed as
//! `upc_memget`-style block copies), while z-faces touch one double per
//! cache line (`col_stride = n`), the same access shape as the 2D
//! horizontal halos. So, as in eq. (19), only the doubly-strided faces are
//! charged `(s·(D + cl))/W_thread`.

use crate::machine::{HwParams, SIZEOF_DOUBLE};
use crate::pgas::Topology;
use crate::stencil3d::Stencil3dGrid;

/// Output of the 3D stencil model.
#[derive(Debug, Clone)]
pub struct Stencil3dPrediction {
    /// Eq. (21) analogue: face-exchange time per step.
    pub t_halo: f64,
    /// Eq. (22) analogue: computation time per step.
    pub t_comp: f64,
    /// Per-thread pack (= unpack) times, eq. (19) analogue.
    pub t_pack: Vec<f64>,
    /// Per-node memget times, eq. (20) analogue.
    pub t_memget_node: Vec<f64>,
}

/// Evaluate the model for one time step.
pub fn predict_stencil3d(
    grid: &Stencil3dGrid,
    topo: &Topology,
    hw: &HwParams,
) -> Stencil3dPrediction {
    assert_eq!(topo.threads(), grid.threads());
    const D: f64 = SIZEOF_DOUBLE as f64;
    let w = hw.w_thread_private;
    let cl = hw.cache_line as f64;
    let threads = grid.threads();

    // Eq. (19) analogue: per-thread pack/unpack — doubly-strided faces
    // only, charged at the measured gather/scatter bandwidth `w_pack`
    // (equal to the STREAM figure on Abel, recovering the paper's term).
    let mut t_pack = vec![0.0f64; threads];
    for (t, tp) in t_pack.iter_mut().enumerate() {
        let s_strided: usize = grid
            .neighbours(t)
            .iter()
            .filter(|&&(_, _, strided)| strided)
            .map(|&(_, len, _)| len)
            .sum();
        *tp = hw.t_pack_stream(s_strided as f64 * (D + cl));
    }

    // Eq. (20) analogue: per-node memget — local transfers concurrent
    // (max), remote serialized on the NIC (sum), each remote message paying
    // τ.
    let mut t_memget_node = vec![0.0f64; topo.nodes];
    for node in 0..topo.nodes {
        let mut local_max = 0.0f64;
        let mut remote_sum = 0.0f64;
        for t in topo.threads_of_node(node) {
            let mut s_local = 0usize;
            let mut s_remote = 0usize;
            let mut c_remote = 0usize;
            for (peer, len, _) in grid.neighbours(t) {
                if topo.same_node(t, peer) {
                    s_local += len;
                } else {
                    s_remote += len;
                    c_remote += 1;
                }
            }
            local_max = local_max.max(2.0 * s_local as f64 * D / w);
            remote_sum += c_remote as f64 * hw.tau + s_remote as f64 * D / hw.w_node_remote;
        }
        t_memget_node[node] = local_max + remote_sum;
    }

    // Eq. (21) analogue: max over nodes of (max pack + memget + max unpack).
    let mut t_halo = 0.0f64;
    for node in 0..topo.nodes {
        let pack_max = topo
            .threads_of_node(node)
            .map(|t| t_pack[t])
            .fold(0.0, f64::max);
        t_halo = t_halo.max(pack_max + t_memget_node[node] + pack_max);
    }

    // Eq. (22) analogue: 3 streamed passes over the interior (read phi with
    // plane reuse in cache, write phin, write-allocate), as in the 2D count.
    let (p, m, n) = grid.subdomain();
    let t_comp = 3.0 * ((p - 2) * (m - 2) * (n - 2)) as f64 * D / w;

    Stencil3dPrediction { t_halo, t_comp, t_pack, t_memget_node }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halo_shrinks_with_more_threads_held_mesh() {
        let hw = HwParams::abel();
        let g8 = Stencil3dGrid::new(480, 480, 480, 2, 2, 2);
        let g64 = Stencil3dGrid::new(480, 480, 480, 4, 4, 4);
        let h8 = predict_stencil3d(&g8, &Topology::new(1, 8), &hw).t_halo;
        let h64 = predict_stencil3d(&g64, &Topology::new(4, 16), &hw).t_halo;
        // Faces shrink quadratically with the per-axis split.
        assert!(h64 < h8, "{h64} !< {h8}");
    }

    #[test]
    fn comp_scales_with_interior() {
        let hw = HwParams::abel();
        let small = Stencil3dGrid::new(96, 96, 96, 2, 2, 2);
        let big = Stencil3dGrid::new(192, 192, 192, 2, 2, 2);
        let ts = predict_stencil3d(&small, &Topology::new(1, 8), &hw).t_comp;
        let tb = predict_stencil3d(&big, &Topology::new(1, 8), &hw).t_comp;
        assert!((tb / ts - 8.0).abs() < 0.2, "8x interior -> 8x comp, got {}", tb / ts);
    }

    #[test]
    fn only_strided_faces_pay_pack() {
        let hw = HwParams::abel();
        // Split along z only: every thread has z-faces (strided).
        let gz = Stencil3dGrid::new(48, 48, 96, 1, 1, 4);
        let pz = predict_stencil3d(&gz, &Topology::new(1, 4), &hw);
        assert!(pz.t_pack.iter().all(|&t| t > 0.0));
        // Split along x only: faces are row-chunked, no pack cost.
        let gx = Stencil3dGrid::new(96, 48, 48, 4, 1, 1);
        let px = predict_stencil3d(&gx, &Topology::new(1, 4), &hw);
        assert!(px.t_pack.iter().all(|&t| t == 0.0));
        // But the x-split still moves bytes: memget time is non-zero.
        assert!(px.t_memget_node.iter().any(|&t| t > 0.0));
    }

    #[test]
    fn remote_topology_costs_more() {
        let hw = HwParams::abel();
        let g = Stencil3dGrid::new(96, 96, 96, 2, 2, 2);
        let one_node = predict_stencil3d(&g, &Topology::new(1, 8), &hw).t_halo;
        let two_nodes = predict_stencil3d(&g, &Topology::new(2, 4), &hw).t_halo;
        assert!(two_nodes > one_node, "{two_nodes} !> {one_node}");
    }
}
