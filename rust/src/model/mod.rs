//! The performance-model engine — the paper's §5 (eqs. (5)–(18)) and the 2D
//! extension of §8.2 (eqs. (19)–(22)).
//!
//! These are the *closed-form predictions*. They are deliberately
//! implemented independently of the [`sim`](crate::sim) module (which
//! executes the same traffic with contention effects), so comparing the two
//! reproduces the paper's "actual vs. predicted" methodology — see Table 4 /
//! Table 5 in the harness.

mod heat;
mod overlap;
mod pipeline;
mod planopt;
mod rebuild;
mod spmv;
mod stencil;

pub use heat::{predict_heat2d, Heat2dPrediction, HeatGrid};
pub use overlap::{
    predict_heat2d_overlap, predict_heat2d_overlap_fused, predict_heat2d_overlap_on,
    predict_stencil3d_overlap, predict_stencil3d_overlap_on, predict_v3_overlap,
    predict_v3_overlap_on, OverlapPrediction,
};
pub use pipeline::{
    choose_depth, predict_heat2d_pipelined, predict_stencil3d_pipelined, predict_v3_pipelined,
    PipelinePrediction,
};
pub use planopt::{comm_seconds_on, predict_planopt_speedup, PlanoptPrediction};
pub use rebuild::{RebuildModel, RebuildPrediction};
pub use spmv::{
    predict_naive, predict_v1, predict_v2, predict_v3, t_comp_thread, SpmvInputs, SpmvPrediction,
    V3ThreadBreakdown,
};
pub use stencil::{predict_stencil3d, Stencil3dPrediction};

use crate::machine::NaiveOverheads;
use crate::spmv::Variant;

/// Dispatch to the per-variant SpMV model. The naive variant uses the
/// calibrated `upc_forall` + pointer-to-shared overheads (the paper measures
/// but does not model it; see [`crate::machine::NaiveOverheads`]).
pub fn predict(variant: Variant, inp: &SpmvInputs) -> SpmvPrediction {
    match variant {
        Variant::Naive => predict_naive(inp, &NaiveOverheads::calibrated()),
        Variant::V1 => predict_v1(inp),
        Variant::V2 => predict_v2(inp),
        Variant::V3 => predict_v3(inp),
    }
}

/// Dispatch to the per-variant overlap model. Only UPCv3 has a split-phase
/// protocol (the other variants have no compiled exchange to overlap), so
/// only it is accepted.
pub fn predict_overlapped(variant: Variant, inp: &SpmvInputs) -> OverlapPrediction {
    assert_eq!(
        variant,
        Variant::V3,
        "the split-phase overlap model exists for UPCv3 only"
    );
    predict_v3_overlap(inp)
}

/// Dispatch to the per-variant pipeline model (a batch of `steps` pipelined
/// iterations). As with the overlap model, only UPCv3 has a compiled
/// exchange to pipeline.
pub fn predict_pipelined(variant: Variant, inp: &SpmvInputs, steps: usize) -> PipelinePrediction {
    assert_eq!(
        variant,
        Variant::V3,
        "the multi-step pipeline model exists for UPCv3 only"
    );
    predict_v3_pipelined(inp, steps)
}
