//! The overlap performance model for split-phase exchanges.
//!
//! The §5/§8 models are strictly serial: pack, bulk transfer, unpack, then
//! compute. The split-phase runtime (`begin_exchange` → interior compute →
//! `finish_exchange` → boundary compute) hides the exchange behind the
//! halo-independent interior, so its step time is modeled as
//!
//! ```text
//! T_step ≈ max(T_comm, T_comp^interior) + T_comp^boundary
//! ```
//!
//! with `T_comm` the serial model's communication term, and the computation
//! term of eqs. (7)/(22) split by the compiled interior/boundary
//! decomposition (cell counts for the grid workloads,
//! [`RowSplit`](crate::comm::RowSplit) row counts for SpMV V3). Validated
//! measured-vs-predicted by `repro validate` like every other variant.

use super::{predict_heat2d, predict_stencil3d, predict_v3, HeatGrid, SpmvInputs};
use crate::comm::RowRun;
use crate::machine::HwParams;
use crate::pgas::Topology;
use crate::stencil3d::Stencil3dGrid;

/// Output of the overlap model for one time step.
#[derive(Debug, Clone, Copy)]
pub struct OverlapPrediction {
    /// The serial model's communication term the interior overlaps with.
    pub t_comm: f64,
    /// Computation on halo-independent data (the overlap window).
    pub t_comp_interior: f64,
    /// Post-`finish_exchange` work: halo-adjacent compute (plus unpack, for
    /// the gather form).
    pub t_comp_boundary: f64,
    /// `max(t_comm, t_comp_interior) + t_comp_boundary`.
    pub t_step: f64,
    /// The synchronous model's step time, for comparison.
    pub t_step_sync: f64,
}

impl OverlapPrediction {
    fn assemble(t_comm: f64, t_int: f64, t_bound: f64, t_sync: f64) -> OverlapPrediction {
        OverlapPrediction {
            t_comm,
            t_comp_interior: t_int,
            t_comp_boundary: t_bound,
            t_step: t_comm.max(t_int) + t_bound,
            t_step_sync: t_sync,
        }
    }

    /// Modeled speedup of the overlapped protocol over the serial one.
    pub fn speedup(&self) -> f64 {
        self.t_step_sync / self.t_step
    }
}

/// Overlap model for the heat-2D workload: eqs. (19)–(22) give `T_halo` and
/// `T_comp`; the compute splits by interior/boundary cell counts of the
/// `(m−2) × (n−2)` owned region (ring width 1, the 5-point stencil radius).
pub fn predict_heat2d_overlap(
    grid: &HeatGrid,
    topo: &Topology,
    hw: &HwParams,
) -> OverlapPrediction {
    let p = predict_heat2d(grid, topo, hw);
    let (m, n) = grid.subdomain();
    let owned = ((m - 2) * (n - 2)) as f64;
    let interior = (m.saturating_sub(4) * n.saturating_sub(4)) as f64;
    let frac = interior / owned;
    OverlapPrediction::assemble(
        p.t_halo,
        p.t_comp * frac,
        p.t_comp * (1.0 - frac),
        p.t_halo + p.t_comp,
    )
}

/// Overlap model for the 3D stencil: same decomposition with the
/// `(p−4) × (m−4) × (n−4)` interior box of the 7-point stencil.
pub fn predict_stencil3d_overlap(
    grid: &Stencil3dGrid,
    topo: &Topology,
    hw: &HwParams,
) -> OverlapPrediction {
    let pr = predict_stencil3d(grid, topo, hw);
    let (p, m, n) = grid.subdomain();
    let owned = ((p - 2) * (m - 2) * (n - 2)) as f64;
    let interior =
        (p.saturating_sub(4) * m.saturating_sub(4) * n.saturating_sub(4)) as f64;
    let frac = interior / owned;
    OverlapPrediction::assemble(
        pr.t_halo,
        pr.t_comp * frac,
        pr.t_comp * (1.0 - frac),
        pr.t_halo + pr.t_comp,
    )
}

/// Overlap model for SpMV UPCv3: phase 1 of eq. (18) (pack + memput) is the
/// communication the interior rows overlap with; the eq. (7) computation
/// splits by the analysis' interior/boundary row counts. The own-block copy
/// (eq. (14)) is owner-local and joins the overlap window; the scattered
/// unpack (eq. (15)) needs the messages and joins the boundary phase.
pub fn predict_v3_overlap(inp: &SpmvInputs) -> OverlapPrediction {
    let sync = predict_v3(inp);
    let threads = inp.layout.threads;

    // Phase 1 of eq. (18): max over nodes of (max pack + node memput).
    let mut t_comm = 0.0f64;
    for node in 0..inp.topo.nodes {
        let mut pack_max = 0.0f64;
        let mut memput = 0.0f64;
        for t in inp.topo.threads_of_node(node) {
            pack_max = pack_max.max(sync.breakdown[t].t_pack);
            memput = sync.breakdown[t].t_comm; // equal across the node
        }
        t_comm = t_comm.max(pack_max + memput);
    }

    let mut t_int = 0.0f64;
    let mut t_bound = 0.0f64;
    for t in 0..threads {
        let split = &inp.analysis.row_split[t];
        let int_rows = RowRun::total(&split.interior);
        let rows = int_rows + RowRun::total(&split.boundary);
        let frac = if rows == 0 { 0.0 } else { int_rows as f64 / rows as f64 };
        let b = &sync.breakdown[t];
        t_int = t_int.max(b.t_copy + sync.t_comp[t] * frac);
        t_bound = t_bound.max(b.t_unpack + sync.t_comp[t] * (1.0 - frac));
    }
    OverlapPrediction::assemble(t_comm, t_int, t_bound, sync.total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Analysis;
    use crate::matrix::Ellpack;
    use crate::pgas::Layout;

    #[test]
    fn overlap_never_slower_than_serial_model() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let p = predict_heat2d_overlap(&grid, &Topology::new(1, 16), &hw);
        assert!(p.t_step > 0.0);
        assert!(p.t_step <= p.t_step_sync + 1e-15, "{} > {}", p.t_step, p.t_step_sync);
        assert!(p.speedup() >= 1.0);
        // The boundary ring is a vanishing fraction on a large subdomain.
        assert!(p.t_comp_boundary < 0.01 * p.t_comp_interior);

        let grid3 = Stencil3dGrid::new(480, 480, 480, 2, 2, 2);
        let p3 = predict_stencil3d_overlap(&grid3, &Topology::new(2, 4), &hw);
        assert!(p3.t_step > 0.0 && p3.t_step <= p3.t_step_sync + 1e-15);
    }

    #[test]
    fn degenerate_interiors_have_no_overlap_window() {
        let hw = HwParams::abel();
        // 1-cell-thick owned regions: everything is boundary, so the
        // overlapped step degenerates to comm + compute.
        let grid = HeatGrid::new(4, 64, 4, 1);
        let p = predict_heat2d_overlap(&grid, &Topology::new(1, 4), &hw);
        assert_eq!(p.t_comp_interior, 0.0);
        assert!((p.t_step - (p.t_comm + p.t_comp_boundary)).abs() < 1e-18);
    }

    #[test]
    fn v3_overlap_splits_by_row_classes() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, m.n.div_ceil(8), 8);
        let topo = Topology::new(2, 4);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let p = predict_v3_overlap(&inp);
        assert!(p.t_step > 0.0 && p.t_comm > 0.0);
        // The overlap window never costs more than serializing its parts.
        assert!(p.t_step <= p.t_comm + p.t_comp_interior + p.t_comp_boundary + 1e-18);
        // A spatially local mesh with whole-chunk ownership has interior
        // rows (the own-block copy alone makes the window non-empty).
        assert!(p.t_comp_interior > 0.0);
        assert!(p.t_comp_boundary > 0.0, "unpack always pays");
    }
}
