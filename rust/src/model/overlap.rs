//! The overlap performance model for split-phase exchanges.
//!
//! The §5/§8 models are strictly serial: pack, bulk transfer, unpack, then
//! compute. The split-phase runtime (`begin_exchange` → interior compute →
//! `finish_exchange` → boundary compute) hides the exchange behind the
//! halo-independent interior — but not all of the exchange: the pack and
//! unpack run *on the compute thread itself*, serially before and after the
//! overlap window, so only the transfer (the memget/memput term the peers
//! and the NIC carry) can actually hide behind the interior. The refined
//! step model is therefore
//!
//! ```text
//! T_step ≈ T_pack + max(T_transfer, T_comp^interior) + T_unpack
//!          + T_comp^boundary
//! ```
//!
//! evaluated per node (pack and transfer bind on the same node in the
//! eqs. (19)–(21) structure) and maximized across nodes, with the
//! computation term of eqs. (7)/(22) split by the compiled
//! interior/boundary decomposition (cell counts for the grid workloads,
//! [`RowSplit`](crate::comm::RowSplit) row counts for SpMV V3; for V3 the
//! unpack is the scattered ghost write that the executor performs inside
//! the boundary phase, so it is folded into `T_comp^boundary` and
//! `t_unpack` reports 0). The earlier model charged the whole serial halo
//! time as overlappable, which under-predicted layouts with strided pack
//! costs; charging pack/unpack serially tightens the overlap rows of
//! `repro validate`. Validated measured-vs-predicted like every other
//! variant.

use super::{predict_heat2d, predict_stencil3d, predict_v3, HeatGrid, SpmvInputs};
use crate::comm::RowRun;
use crate::machine::{HwParams, TransportModel};
use crate::pgas::Topology;
use crate::stencil3d::Stencil3dGrid;

/// Output of the overlap model for one time step.
#[derive(Debug, Clone, Copy)]
pub struct OverlapPrediction {
    /// Same-thread pack time at the binding node (serial, before the
    /// overlap window opens).
    pub t_pack: f64,
    /// The transfer term the interior overlaps with (memget/memput at the
    /// binding node).
    pub t_comm: f64,
    /// Same-thread unpack time at the binding node (serial, after
    /// `finish_exchange`; 0 for SpMV V3 where the scatter is part of the
    /// boundary phase).
    pub t_unpack: f64,
    /// Largest per-node transfer term across **all** nodes (≥ `t_comm`).
    /// A node whose transfer is large but whose pack is small may not bind
    /// the overlap window, yet it is still the resource floor a multi-step
    /// pipeline cannot amortize below — the pipeline model's steady state
    /// uses this, not the binding node's `t_comm`.
    pub t_comm_max: f64,
    /// Largest per-node pack / unpack terms across **all** nodes
    /// (≥ `t_pack` / `t_unpack`). Same cross-node reasoning as
    /// `t_comm_max`, for the serial chain: a node with little transfer can
    /// still gate the pipeline's steady state through its same-thread
    /// pack/unpack work.
    pub t_pack_max: f64,
    pub t_unpack_max: f64,
    /// Computation on halo-independent data (the overlap window).
    pub t_comp_interior: f64,
    /// Post-`finish_exchange` work: halo-adjacent compute (plus the
    /// scattered unpack, for the gather form).
    pub t_comp_boundary: f64,
    /// `max over nodes (pack + max(transfer, interior) + unpack) +
    /// boundary`.
    pub t_step: f64,
    /// The synchronous model's step time, for comparison.
    pub t_step_sync: f64,
}

impl OverlapPrediction {
    /// Modeled speedup of the overlapped protocol over the serial one.
    pub fn speedup(&self) -> f64 {
        self.t_step_sync / self.t_step
    }

    /// The fused-boundary refinement: a fraction `fused_frac` of the
    /// serial unpack is folded into the boundary compute (the fused
    /// kernel reads the staged ghost cells directly while evaluating the
    /// boundary stencil, so the separate scatter pass for those messages
    /// disappears; the boundary compute itself is unchanged — it read
    /// those cells anyway). Subtracts that share from `t_unpack`,
    /// `t_unpack_max` and the step. Only meaningful for workloads whose
    /// unpack is charged in `t_unpack` (strided/indexed traffic); see
    /// [`predict_heat2d_overlap_fused`] for heat-2D, where the fused
    /// messages are the *contiguous* ghost rows eq. (19) never charges.
    pub fn with_fused_unpack(&self, fused_frac: f64) -> OverlapPrediction {
        assert!(
            (0.0..=1.0).contains(&fused_frac),
            "fused fraction must be in [0, 1], got {fused_frac}"
        );
        let cut = fused_frac * self.t_unpack;
        let cut_max = fused_frac * self.t_unpack_max;
        OverlapPrediction {
            t_unpack: self.t_unpack - cut,
            t_unpack_max: self.t_unpack_max - cut_max,
            t_step: self.t_step - cut,
            ..*self
        }
    }
}

/// Evaluate the refined per-node window `pack + max(transfer, interior) +
/// unpack`, maximized over nodes. `node_terms` yields each node's
/// `(pack, transfer, unpack)` triple; returns the binding node's triple,
/// the window time, and the component-wise `(pack, transfer, unpack)`
/// maxima across all nodes (the pipeline model's resource floors).
fn bind_window(
    node_terms: impl Iterator<Item = (f64, f64, f64)>,
    t_interior: f64,
) -> ((f64, f64, f64), f64, (f64, f64, f64)) {
    let mut best = (0.0f64, 0.0f64, 0.0f64);
    let mut best_term = f64::NEG_INFINITY;
    let mut maxima = (0.0f64, 0.0f64, 0.0f64);
    for (pack, transfer, unpack) in node_terms {
        let term = pack + transfer.max(t_interior) + unpack;
        if term > best_term {
            best_term = term;
            best = (pack, transfer, unpack);
        }
        maxima = (maxima.0.max(pack), maxima.1.max(transfer), maxima.2.max(unpack));
    }
    // A topology always has ≥ 1 node, and every node term already includes
    // the interior window; the max guards the degenerate empty iterator.
    (best, best_term.max(t_interior), maxima)
}

/// Overlap model for the heat-2D workload: eqs. (19)–(22) give the per-node
/// pack and memget terms; the compute splits by interior/boundary cell
/// counts of the `(m−2) × (n−2)` owned region (ring width 1, the 5-point
/// stencil radius). Pack = unpack as in eq. (21).
pub fn predict_heat2d_overlap(
    grid: &HeatGrid,
    topo: &Topology,
    hw: &HwParams,
) -> OverlapPrediction {
    let p = predict_heat2d(grid, topo, hw);
    let (m, n) = grid.subdomain();
    let owned = ((m - 2) * (n - 2)) as f64;
    let interior = (m.saturating_sub(4) * n.saturating_sub(4)) as f64;
    let frac = interior / owned;
    let t_int = p.t_comp * frac;
    let t_bound = p.t_comp * (1.0 - frac);
    let terms = (0..topo.nodes).map(|node| {
        let pack_max = topo
            .threads_of_node(node)
            .map(|t| p.t_pack[t])
            .fold(0.0, f64::max);
        (pack_max, p.t_memget_node[node], pack_max)
    });
    let ((t_pack, t_comm, t_unpack), window, (t_pack_max, t_comm_max, t_unpack_max)) =
        bind_window(terms, t_int);
    OverlapPrediction {
        t_pack,
        t_comm,
        t_unpack,
        t_comm_max,
        t_pack_max,
        t_unpack_max,
        t_comp_interior: t_int,
        t_comp_boundary: t_bound,
        t_step: window + t_bound,
        t_step_sync: p.t_halo + p.t_comp,
    }
}

/// Overlap model for heat-2D with the fused boundary step
/// ([`step_fused`](crate::heat2d::Heat2dSolver::step_fused)): the up/down
/// ghost-row unpacks fold into the boundary Jacobi. Eq. (19)'s `t_pack`
/// charges only the strided horizontal traffic — the fused messages are
/// the *contiguous* rows, whose staging-runtime copy (one load + one
/// store per element) the paper model never itemizes — so the saving is
/// computed directly from the subdomain geometry and taken off the step,
/// rather than as a fraction of `t_unpack`. Subdomains too short to fuse
/// (`m < 4`, where the runtime falls back to plain unpack) predict
/// identically to [`predict_heat2d_overlap`].
pub fn predict_heat2d_overlap_fused(
    grid: &HeatGrid,
    topo: &Topology,
    hw: &HwParams,
) -> OverlapPrediction {
    let p = predict_heat2d_overlap(grid, topo, hw);
    let (m, n) = grid.subdomain();
    if m < 4 || n < 3 {
        return p;
    }
    const D: f64 = crate::machine::SIZEOF_DOUBLE as f64;
    // Two ghost rows of n−2 elements per interior thread, each saved copy
    // a contiguous load + store.
    let t_rows = hw.t_private_stream(2.0 * (n - 2) as f64 * 2.0 * D);
    OverlapPrediction { t_step: (p.t_step - t_rows).max(0.0), ..p }
}

/// Overlap model for the 3D stencil: same decomposition with the
/// `(p−4) × (m−4) × (n−4)` interior box of the 7-point stencil.
pub fn predict_stencil3d_overlap(
    grid: &Stencil3dGrid,
    topo: &Topology,
    hw: &HwParams,
) -> OverlapPrediction {
    let pr = predict_stencil3d(grid, topo, hw);
    let (p, m, n) = grid.subdomain();
    let owned = ((p - 2) * (m - 2) * (n - 2)) as f64;
    let interior =
        (p.saturating_sub(4) * m.saturating_sub(4) * n.saturating_sub(4)) as f64;
    let frac = interior / owned;
    let t_int = pr.t_comp * frac;
    let t_bound = pr.t_comp * (1.0 - frac);
    let terms = (0..topo.nodes).map(|node| {
        let pack_max = topo
            .threads_of_node(node)
            .map(|t| pr.t_pack[t])
            .fold(0.0, f64::max);
        (pack_max, pr.t_memget_node[node], pack_max)
    });
    let ((t_pack, t_comm, t_unpack), window, (t_pack_max, t_comm_max, t_unpack_max)) =
        bind_window(terms, t_int);
    OverlapPrediction {
        t_pack,
        t_comm,
        t_unpack,
        t_comm_max,
        t_pack_max,
        t_unpack_max,
        t_comp_interior: t_int,
        t_comp_boundary: t_bound,
        t_step: window + t_bound,
        t_step_sync: pr.t_halo + pr.t_comp,
    }
}

/// Overlap model for SpMV UPCv3: the same-thread arena fill of eq. (18)'s
/// phase 1 is the serial pack, the node-level memput its overlappable
/// transfer; the eq. (7) computation splits by the analysis'
/// interior/boundary row counts. The own-block copy (eq. (14)) is
/// owner-local and joins the overlap window; the scattered unpack
/// (eq. (15)) needs the messages and joins the boundary phase (so
/// `t_unpack` reports 0 here).
pub fn predict_v3_overlap(inp: &SpmvInputs) -> OverlapPrediction {
    let sync = predict_v3(inp);
    let threads = inp.layout.threads;

    let mut t_int = 0.0f64;
    let mut t_bound = 0.0f64;
    for t in 0..threads {
        let split = &inp.analysis.row_split[t];
        let int_rows = RowRun::total(&split.interior);
        let rows = int_rows + RowRun::total(&split.boundary);
        let frac = if rows == 0 { 0.0 } else { int_rows as f64 / rows as f64 };
        let b = &sync.breakdown[t];
        t_int = t_int.max(b.t_copy + sync.t_comp[t] * frac);
        t_bound = t_bound.max(b.t_unpack + sync.t_comp[t] * (1.0 - frac));
    }

    // Eq. (18) phase 1 per node: max same-thread pack + node memput.
    let terms = (0..inp.topo.nodes).map(|node| {
        let mut pack_max = 0.0f64;
        let mut memput = 0.0f64;
        for t in inp.topo.threads_of_node(node) {
            pack_max = pack_max.max(sync.breakdown[t].t_pack);
            memput = sync.breakdown[t].t_comm; // equal across the node
        }
        (pack_max, memput, 0.0)
    });
    let ((t_pack, t_comm, t_unpack), window, (t_pack_max, t_comm_max, t_unpack_max)) =
        bind_window(terms, t_int);
    OverlapPrediction {
        t_pack,
        t_comm,
        t_unpack,
        t_comm_max,
        t_pack_max,
        t_unpack_max,
        t_comp_interior: t_int,
        t_comp_boundary: t_bound,
        t_step: window + t_bound,
        t_step_sync: sync.total,
    }
}

// ---------------------------------------------------------------------------
// Transport-parameterized entry points.
//
// The models above take the interconnect's τ and bandwidth as measured
// inputs, which makes them transport-portable: evaluating "the same
// workload over sockets" is the same closed form with the socket probe's
// (latency, bandwidth) substituted via [`TransportModel::apply`]. These
// wrappers perform the substitution so callers (`repro validate
// --transport …`) cannot forget it on one path.
// ---------------------------------------------------------------------------

/// [`predict_heat2d_overlap`] with `tm`'s remote terms substituted into
/// `hw`.
pub fn predict_heat2d_overlap_on(
    tm: &TransportModel,
    grid: &HeatGrid,
    topo: &Topology,
    hw: &HwParams,
) -> OverlapPrediction {
    predict_heat2d_overlap(grid, topo, &tm.apply(hw))
}

/// [`predict_stencil3d_overlap`] with `tm`'s remote terms substituted into
/// `hw`.
pub fn predict_stencil3d_overlap_on(
    tm: &TransportModel,
    grid: &Stencil3dGrid,
    topo: &Topology,
    hw: &HwParams,
) -> OverlapPrediction {
    predict_stencil3d_overlap(grid, topo, &tm.apply(hw))
}

/// [`predict_v3_overlap`] with `tm`'s remote terms substituted into the
/// inputs' `hw`.
pub fn predict_v3_overlap_on(tm: &TransportModel, inp: &SpmvInputs) -> OverlapPrediction {
    predict_v3_overlap(&SpmvInputs { hw: tm.apply(&inp.hw), ..*inp })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Analysis;
    use crate::matrix::Ellpack;
    use crate::pgas::Layout;

    #[test]
    fn transport_substitution_slows_remote_terms_only() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(4_096, 4_096, 2, 2);
        let topo = Topology::new(4, 1);
        let base = predict_heat2d_overlap_on(&TransportModel::inproc(), &grid, &topo, &hw);
        let ref_direct = predict_heat2d_overlap(&grid, &topo, &hw);
        assert_eq!(base.t_step, ref_direct.t_step, "inproc wrapper is the identity");
        // A much slower interconnect (loopback-socket-ish) inflates the
        // transfer term but leaves the compute split untouched.
        let slow = TransportModel::socket(50.0e-6, 1.0e9);
        let p = predict_heat2d_overlap_on(&slow, &grid, &topo, &hw);
        assert!(p.t_comm > base.t_comm);
        assert_eq!(p.t_comp_interior, base.t_comp_interior);
        assert_eq!(p.t_comp_boundary, base.t_comp_boundary);
    }

    #[test]
    fn overlap_never_slower_than_serial_model() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let p = predict_heat2d_overlap(&grid, &Topology::new(1, 16), &hw);
        assert!(p.t_step > 0.0);
        assert!(p.t_step <= p.t_step_sync + 1e-15, "{} > {}", p.t_step, p.t_step_sync);
        assert!(p.speedup() >= 1.0);
        assert!(p.t_comm_max >= p.t_comm, "the all-node floor dominates the binding node");
        // The boundary ring is a vanishing fraction on a large subdomain.
        assert!(p.t_comp_boundary < 0.01 * p.t_comp_interior);

        let grid3 = Stencil3dGrid::new(480, 480, 480, 2, 2, 2);
        let p3 = predict_stencil3d_overlap(&grid3, &Topology::new(2, 4), &hw);
        assert!(p3.t_step > 0.0 && p3.t_step <= p3.t_step_sync + 1e-15);
    }

    #[test]
    fn pack_and_unpack_charged_serially() {
        // A column-split layout (1×N): every halo is a strided column, so
        // pack time is non-zero — and the refined model must charge it
        // outside the overlap window: t_step ≥ pack + unpack + interior.
        let hw = HwParams::abel();
        let grid = HeatGrid::new(8_192, 8_192, 1, 8);
        let p = predict_heat2d_overlap(&grid, &Topology::new(1, 8), &hw);
        assert!(p.t_pack > 0.0, "strided halos must pay pack time");
        assert_eq!(p.t_pack, p.t_unpack, "pack and unpack are modeled equal");
        let floor = p.t_pack + p.t_unpack + p.t_comp_interior + p.t_comp_boundary;
        assert!(
            p.t_step >= floor - 1e-12,
            "pack/unpack not serial: {} < {floor}",
            p.t_step
        );
        // The old model (whole halo overlappable) predicted strictly less
        // whenever the interior dominates the transfer — the refinement
        // only raises predictions, i.e. tightens measured/predicted from
        // above.
        let old = (p.t_pack + p.t_comm + p.t_unpack).max(p.t_comp_interior)
            + p.t_comp_boundary;
        assert!(p.t_step >= old - 1e-12);
    }

    #[test]
    fn degenerate_interiors_have_no_overlap_window() {
        let hw = HwParams::abel();
        // 1-cell-thick owned regions: everything is boundary, so the
        // overlapped step degenerates to the serial chain.
        let grid = HeatGrid::new(4, 64, 4, 1);
        let p = predict_heat2d_overlap(&grid, &Topology::new(1, 4), &hw);
        assert_eq!(p.t_comp_interior, 0.0);
        let serial = p.t_pack + p.t_comm + p.t_unpack + p.t_comp_boundary;
        assert!((p.t_step - serial).abs() < 1e-18);
    }

    #[test]
    fn fused_unpack_shaves_the_step() {
        let hw = HwParams::abel();
        // Strided halos (column split) so t_unpack is non-zero.
        let grid = HeatGrid::new(8_192, 8_192, 1, 8);
        let topo = Topology::new(1, 8);
        let p = predict_heat2d_overlap(&grid, &topo, &hw);
        assert!(p.t_unpack > 0.0);
        // frac 0 is the identity, frac 1 zeroes the unpack, anything in
        // between interpolates and never raises the step.
        let same = p.with_fused_unpack(0.0);
        assert_eq!(same.t_step, p.t_step);
        assert_eq!(same.t_unpack, p.t_unpack);
        let all = p.with_fused_unpack(1.0);
        assert_eq!(all.t_unpack, 0.0);
        assert!((all.t_step - (p.t_step - p.t_unpack)).abs() < 1e-18);
        let half = p.with_fused_unpack(0.5);
        assert!(half.t_step < p.t_step && half.t_step > all.t_step);
        // Untouched terms survive.
        assert_eq!(half.t_pack, p.t_pack);
        assert_eq!(half.t_comp_boundary, p.t_comp_boundary);
        assert_eq!(half.t_step_sync, p.t_step_sync);
    }

    #[test]
    fn heat2d_fused_model_matches_runtime_gate() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(4_096, 4_096, 4, 4);
        let topo = Topology::new(1, 16);
        let base = predict_heat2d_overlap(&grid, &topo, &hw);
        let fused = predict_heat2d_overlap_fused(&grid, &topo, &hw);
        assert!(fused.t_step < base.t_step, "{} !< {}", fused.t_step, base.t_step);
        // Everything except the step is untouched (the saving is the
        // contiguous row copies, itemized nowhere else).
        assert_eq!(fused.t_unpack, base.t_unpack);
        assert_eq!(fused.t_comp_boundary, base.t_comp_boundary);
        // A subdomain too short to fuse predicts identically, mirroring
        // the runtime's fallback: 4 grid rows over 4 thread rows → one
        // owned row per thread, m = 3 < 4.
        let short = HeatGrid::new(4, 4_096, 4, 1);
        let ps = predict_heat2d_overlap(&short, &Topology::new(1, 4), &hw);
        let fs = predict_heat2d_overlap_fused(&short, &Topology::new(1, 4), &hw);
        assert_eq!(fs.t_step, ps.t_step);
    }

    #[test]
    fn v3_overlap_splits_by_row_classes() {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, m.n.div_ceil(8), 8);
        let topo = Topology::new(2, 4);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let p = predict_v3_overlap(&inp);
        assert!(p.t_step > 0.0 && p.t_comm > 0.0);
        // The scattered unpack is folded into the boundary phase for V3.
        assert_eq!(p.t_unpack, 0.0);
        // The overlap window never costs more than serializing its parts.
        assert!(
            p.t_step
                <= p.t_pack + p.t_comm + p.t_comp_interior + p.t_comp_boundary + 1e-18
        );
        // A spatially local mesh with whole-chunk ownership has interior
        // rows (the own-block copy alone makes the window non-empty).
        assert!(p.t_comp_interior > 0.0);
        assert!(p.t_comp_boundary > 0.0, "unpack always pays");
    }
}
