//! SpMV performance models — eqs. (5)–(18), per single SpMV iteration.

use crate::comm::Analysis;
use crate::machine::{HwParams, NaiveOverheads, PTR_ACCESSES_PER_ROW, SIZEOF_DOUBLE};
use crate::pgas::{Layout, Topology};

/// Everything the models consume.
#[derive(Debug, Clone, Copy)]
pub struct SpmvInputs<'a> {
    pub layout: Layout,
    pub topo: Topology,
    pub hw: HwParams,
    pub r_nz: usize,
    pub analysis: &'a Analysis,
}

/// A prediction: total plus the per-thread / per-node pieces it was
/// assembled from (Figure 1 plots these).
#[derive(Debug, Clone)]
pub struct SpmvPrediction {
    /// Predicted time of one SpMV iteration (seconds).
    pub total: f64,
    /// Per-thread computation time, eq. (7).
    pub t_comp: Vec<f64>,
    /// Per-thread communication time (v1: eq. (10)) or per-thread pack /
    /// copy / unpack breakdown (v3, eqs. (12), (14), (15)); empty for
    /// variants where the paper models communication per node.
    pub breakdown: Vec<V3ThreadBreakdown>,
    /// Per-node communication time (v2: eq. (11), v3: eq. (13)).
    pub t_comm_node: Vec<f64>,
}

/// Per-thread components of the UPCv3 model (Figure 1's three series).
#[derive(Debug, Clone, Copy, Default)]
pub struct V3ThreadBreakdown {
    pub t_pack: f64,
    pub t_copy: f64,
    pub t_unpack: f64,
    pub t_comm: f64,
}

/// Eq. (5)+(7): per-thread minimum computation time.
///
/// The paper's formula uses `B_thread^comp · BLOCKSIZE` rows (i.e. it rounds
/// the tail block up to a full block); we reproduce that faithfully.
pub fn t_comp_thread(layout: &Layout, hw: &HwParams, r_nz: usize, thread: usize) -> f64 {
    let b_comp = layout.nblks_of_thread(thread) as f64;
    let d_min = (r_nz * (SIZEOF_DOUBLE + crate::machine::SIZEOF_INT) + 3 * SIZEOF_DOUBLE) as f64; // eq. (6)
    b_comp * layout.block_size as f64 * d_min / hw.w_thread_private
}

/// Eq. (10)+(16): the UPCv1 model.
pub fn predict_v1(inp: &SpmvInputs) -> SpmvPrediction {
    let threads = inp.layout.threads;
    let mut t_comp = Vec::with_capacity(threads);
    let mut per_thread_total = Vec::with_capacity(threads);
    for t in 0..threads {
        let comp = t_comp_thread(&inp.layout, &inp.hw, inp.r_nz, t);
        let tt = &inp.analysis.per_thread[t];
        // Eq. (10)
        let comm = tt.c_local_indv as f64 * inp.hw.t_indv_local()
            + tt.c_remote_indv as f64 * inp.hw.t_indv_remote();
        t_comp.push(comp);
        per_thread_total.push(comp + comm);
    }
    // Eq. (16): max over threads.
    let total = per_thread_total.iter().copied().fold(0.0, f64::max);
    SpmvPrediction { total, t_comp, breakdown: Vec::new(), t_comm_node: Vec::new() }
}

/// The naive model: UPCv1 plus the calibrated `upc_forall` + pointer-to-
/// shared overheads of Listing 2 (the paper measures but does not model the
/// naive version; see `machine::NaiveOverheads`).
pub fn predict_naive(inp: &SpmvInputs, ov: &NaiveOverheads) -> SpmvPrediction {
    let base = predict_v1(inp);
    let threads = inp.layout.threads;
    let n = inp.layout.n as f64;
    let mut worst = 0.0f64;
    let mut t_comp = base.t_comp.clone();
    for t in 0..threads {
        let rows = inp.layout.nelems_of_thread(t) as f64;
        let tt = &inp.analysis.per_thread[t];
        let comm = tt.c_local_indv as f64 * inp.hw.t_indv_local()
            + tt.c_remote_indv as f64 * inp.hw.t_indv_remote();
        let overhead = n * ov.c_forall + rows * PTR_ACCESSES_PER_ROW * ov.c_ptr;
        t_comp[t] += overhead;
        worst = worst.max(base.t_comp[t] + comm + overhead);
    }
    SpmvPrediction { total: worst, t_comp, breakdown: Vec::new(), t_comm_node: Vec::new() }
}

/// Eq. (11)+(17): the UPCv2 model.
pub fn predict_v2(inp: &SpmvInputs) -> SpmvPrediction {
    let threads = inp.layout.threads;
    let bs_bytes = (inp.layout.block_size * SIZEOF_DOUBLE) as f64;
    let t_comp: Vec<f64> =
        (0..threads).map(|t| t_comp_thread(&inp.layout, &inp.hw, inp.r_nz, t)).collect();

    let mut t_comm_node = Vec::with_capacity(inp.topo.nodes);
    let mut total = 0.0f64;
    for node in 0..inp.topo.nodes {
        // Eq. (11): intra-node gets run concurrently (max over threads);
        // inter-node transfers serialize on the node's interconnect (sum).
        let mut local_max = 0.0f64;
        let mut remote_sum = 0.0f64;
        let mut comp_max = 0.0f64;
        for t in inp.topo.threads_of_node(node) {
            let tt = &inp.analysis.per_thread[t];
            let local = tt.b_local as f64 * 2.0 * bs_bytes / inp.hw.w_thread_private;
            local_max = local_max.max(local);
            remote_sum += tt.b_remote as f64 * (inp.hw.tau + bs_bytes / inp.hw.w_node_remote);
            comp_max = comp_max.max(t_comp[t]);
        }
        let comm = local_max + remote_sum;
        t_comm_node.push(comm);
        // Eq. (17): max over nodes of (max comp + node comm).
        total = total.max(comp_max + comm);
    }
    SpmvPrediction { total, t_comp, breakdown: Vec::new(), t_comm_node }
}

/// Eqs. (12)–(15)+(18): the UPCv3 model.
pub fn predict_v3(inp: &SpmvInputs) -> SpmvPrediction {
    let threads = inp.layout.threads;
    let hw = &inp.hw;
    let w = hw.w_thread_private;
    const D: f64 = SIZEOF_DOUBLE as f64;
    const I: f64 = crate::machine::SIZEOF_INT as f64;
    let cl = hw.cache_line as f64;

    let t_comp: Vec<f64> =
        (0..threads).map(|t| t_comp_thread(&inp.layout, &inp.hw, inp.r_nz, t)).collect();
    let mut breakdown = vec![V3ThreadBreakdown::default(); threads];
    for (t, b) in breakdown.iter_mut().enumerate() {
        let tt = &inp.analysis.per_thread[t];
        // Eq. (12): pack — indexed load of value + its index, store into
        // the message; charged at the gather/scatter bandwidth `w_pack`.
        b.t_pack = hw.t_pack_stream((tt.s_local_out + tt.s_remote_out) as f64 * (2.0 * D + I));
        // Eq. (14): copy own blocks into mythread_x_copy (load + store) —
        // a contiguous stream, so it stays on `w_thread_private`.
        b.t_copy =
            2.0 * inp.layout.nblks_of_thread(t) as f64 * inp.layout.block_size as f64 * D / w;
        // Eq. (15): unpack — contiguous read of the message, scattered
        // write through the index list; also a `w_pack` access pattern.
        b.t_unpack = hw.t_pack_stream((tt.s_local_in + tt.s_remote_in) as f64 * (D + I + cl));
    }

    // Eq. (13): per-node memput cost.
    let mut t_comm_node = Vec::with_capacity(inp.topo.nodes);
    let mut phase1 = 0.0f64; // max over nodes of (max pack + node memput)
    for node in 0..inp.topo.nodes {
        let mut local_max = 0.0f64;
        let mut remote_sum = 0.0f64;
        let mut pack_max = 0.0f64;
        for t in inp.topo.threads_of_node(node) {
            let tt = &inp.analysis.per_thread[t];
            local_max = local_max.max(2.0 * tt.s_local_out as f64 * D / w);
            remote_sum += tt.c_remote_out as f64 * hw.tau
                + tt.s_remote_out as f64 * D / hw.w_node_remote;
            pack_max = pack_max.max(breakdown[t].t_pack);
        }
        let memput = local_max + remote_sum;
        for t in inp.topo.threads_of_node(node) {
            breakdown[t].t_comm = memput;
        }
        t_comm_node.push(memput);
        phase1 = phase1.max(pack_max + memput);
    }

    // Eq. (18): barrier splits the model into two global maxima.
    let mut phase2 = 0.0f64;
    for t in 0..threads {
        phase2 = phase2.max(breakdown[t].t_copy + breakdown[t].t_unpack + t_comp[t]);
    }
    SpmvPrediction { total: phase1 + phase2, t_comp, breakdown, t_comm_node }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Ellpack;
    use crate::sim::DEFAULT_CACHE_WINDOW;

    fn setup(
        n: usize,
        bs: usize,
        nodes: usize,
        tpn: usize,
    ) -> (Ellpack, Layout, Topology, Analysis) {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let n = m.n.min(n);
        let _ = n;
        let layout = Layout::new(m.n, bs, nodes * tpn);
        let topo = Topology::new(nodes, tpn);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, DEFAULT_CACHE_WINDOW);
        (m, layout, topo, a)
    }

    #[test]
    fn eq7_computation_time() {
        let hw = HwParams::abel();
        // Paper's Test problem 1 at 16 threads, BLOCKSIZE=65536:
        // B_total = ceil(6810586/65536) = 104 blocks; 8 threads get 7, 8 get 6.
        let layout = Layout::new(6_810_586, 65_536, 16);
        assert_eq!(layout.nblks(), 104);
        let t0 = t_comp_thread(&layout, &hw, 16, 0);
        // 7 blocks · 65536 · 216 B / 4.6875 GB/s ≈ 21.1 ms
        let expect = 7.0 * 65_536.0 * 216.0 / (75.0e9 / 16.0);
        assert!((t0 - expect).abs() < 1e-12, "{t0} vs {expect}");
        // 1000 iterations ≈ 21.1 s — same order as the paper's 16-thread
        // UPCv1/UPCv3 measurements (26–29 s), as expected.
        assert!(t0 * 1000.0 > 15.0 && t0 * 1000.0 < 30.0);
    }

    #[test]
    fn v1_total_is_max_of_thread_sums() {
        let (m, layout, topo, a) = setup(0, 128, 2, 4);
        let inp = SpmvInputs {
            layout,
            topo,
            hw: HwParams::abel(),
            r_nz: m.r_nz,
            analysis: &a,
        };
        let p = predict_v1(&inp);
        assert!(p.total > 0.0);
        // total ≥ every thread's comp
        for t in 0..layout.threads {
            assert!(p.total + 1e-15 >= p.t_comp[t]);
        }
    }

    #[test]
    fn multinode_v1_pays_tau() {
        let (m, layout1, _, a1) = setup(0, 128, 1, 8);
        let (_, layout2, topo2, a2) = setup(0, 128, 2, 4);
        let hw = HwParams::abel();
        let p1 = predict_v1(&SpmvInputs { layout: layout1, topo: Topology::single_node(8), hw, r_nz: m.r_nz, analysis: &a1 });
        let p2 = predict_v1(&SpmvInputs { layout: layout2, topo: topo2, hw, r_nz: m.r_nz, analysis: &a2 });
        // Crossing nodes makes v1 drastically slower (the paper's Table 3
        // 16→32 thread cliff).
        assert!(p2.total > 3.0 * p1.total, "v1 1-node {} vs 2-node {}", p1.total, p2.total);
    }

    #[test]
    fn v3_beats_v2_beats_v1_multinode() {
        // Paper regime: BLOCKSIZE ≫ stencil span, several blocks/thread.
        let mesh = crate::mesh::TetMesh::generate(
            &crate::mesh::TetGridSpec::ventricle(100_000, 3),
        );
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, m.n / 64, 16);
        let topo = Topology::new(4, 4);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, DEFAULT_CACHE_WINDOW);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let (v1, v2, v3) = (predict_v1(&inp).total, predict_v2(&inp).total, predict_v3(&inp).total);
        assert!(v3 < v2, "v3 {v3} !< v2 {v2}");
        assert!(v2 < v1, "v2 {v2} !< v1 {v1}");
    }

    #[test]
    fn single_node_v1_beats_v2() {
        // The paper's observed exception (Table 3, 16 threads): without the
        // remote-τ penalty v1 wins because v2 transports whole blocks. The
        // effect needs the paper's regime BLOCKSIZE ≫ stencil bandwidth, so
        // use a larger mesh with blocks ≈ n/20.
        let mesh = crate::mesh::TetMesh::generate(
            &crate::mesh::TetGridSpec::ventricle(100_000, 3),
        );
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, m.n / 16, 16); // 1 block/thread, paper Table-4 style
        let topo = Topology::single_node(16);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, DEFAULT_CACHE_WINDOW);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let v1 = predict_v1(&inp).total;
        let v2 = predict_v2(&inp).total;
        assert!(v1 < v2, "single-node v1 {v1} should beat v2 {v2}");
    }

    #[test]
    fn naive_dominates_v1() {
        let (m, layout, topo, a) = setup(0, 128, 1, 8);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let v1 = predict_v1(&inp).total;
        let naive = predict_naive(&inp, &NaiveOverheads::calibrated()).total;
        assert!(naive > 2.0 * v1, "naive {naive} vs v1 {v1}");
    }

    #[test]
    fn v3_breakdown_components_positive() {
        let (m, layout, topo, a) = setup(0, 128, 2, 4);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let p = predict_v3(&inp);
        assert_eq!(p.breakdown.len(), layout.threads);
        for b in &p.breakdown {
            assert!(b.t_copy > 0.0);
            assert!(b.t_pack >= 0.0 && b.t_unpack >= 0.0);
        }
        // Total exceeds any single phase.
        let max_phase2 = p
            .breakdown
            .iter()
            .zip(&p.t_comp)
            .map(|(b, c)| b.t_copy + b.t_unpack + c)
            .fold(0.0, f64::max);
        assert!(p.total >= max_phase2);
    }
}
