//! The multi-step pipeline performance model.
//!
//! The pipelined driver (`run_pipelined`) removes the per-step pool
//! dispatch and every global barrier: across a batch of `S` steps a thread
//! only ever waits on its own senders' publishes and its own receivers'
//! depth-2 acks, so in steady state the per-step cost is the larger of the
//! two resources that cannot be hidden behind each other — the overlappable
//! transfer and the thread's own serial chain (pack, interior, unpack,
//! boundary; pack/unpack are same-thread, see
//! [`overlap`](crate::model::OverlapPrediction)):
//!
//! ```text
//! T_steady    = max(T_transfer, T_pack + T_comp^int + T_unpack + T_comp^bnd)
//! T_total(S)  ≈ S · T_steady + T_fill/drain
//! T_fill/drain = (T_transfer + T_serial) − T_steady  = min(T_transfer, T_serial)
//! ```
//!
//! The fill/drain term is the un-overlapped remainder of the first and last
//! epochs: the pipeline needs one epoch to ramp up (the first transfer has
//! no previous interior to hide behind) and one to drain. For `S = 1` the
//! formula degrades to the fully serial `T_transfer + T_serial`; as
//! `S → ∞` the per-step cost converges to `T_steady` from above — never
//! below the overlapped single-step model's steady term, but strictly
//! below the overlapped *step* whenever both resources are non-trivial,
//! because the pipeline also hides each epoch's residual wait behind the
//! next epoch's work.
//!
//! ## Buffer depth
//!
//! The runtime stages receives through `D` buffers
//! ([`set_depth`](crate::engine::ExchangeRuntime::set_depth)): a sender
//! may run at most `D` epochs ahead of its slowest receiver's ack.
//! [`PipelinePrediction::from_overlap_depth`] extends the model:
//!
//! * `D = 1` — the ack for epoch `e` must arrive before anything of epoch
//!   `e + 1` is packed, so epochs serialize: no cross-epoch amortization,
//!   every step pays the full overlapped step `T_step`, *plus* the ack
//!   round-trip `2τ` — the ack is published at the end of the receiver's
//!   epoch and needed at the start of the sender's next, so nothing can
//!   hide its flight.
//! * `D ≥ 2` — the steady state holds, and `D − 1` epochs of slack absorb
//!   the ack round-trip: `T_gate = max(0, 2τ − (D−1)·T_steady)`, a
//!   per-step stall that is already zero at `D = 2` for any steady state
//!   longer than `2τ` and vanishes entirely as `D` grows. Deeper buffers
//!   therefore only help when the steady state is shorter than the ack
//!   latency — exactly the fine-grained regime the paper's τ-dominated
//!   models describe.
//!
//! [`choose_depth`] scans `D = 1..=4` and returns the smallest depth that
//! minimizes the modeled batch time — the model-driven default for the
//! `--depth` CLI flag.

use super::{
    predict_heat2d_overlap, predict_stencil3d_overlap, predict_v3_overlap, HeatGrid,
    OverlapPrediction, SpmvInputs,
};
use crate::machine::HwParams;
use crate::pgas::Topology;
use crate::stencil3d::Stencil3dGrid;

/// Output of the pipeline model for a batch of `steps` time steps.
#[derive(Debug, Clone, Copy)]
pub struct PipelinePrediction {
    /// Batch size the prediction was evaluated for.
    pub steps: usize,
    /// The overlappable transfer term per step (largest across all nodes).
    pub t_comm: f64,
    /// The same-thread serial chain per step: pack + interior + unpack +
    /// boundary, with pack/unpack taken at their cross-node maxima.
    pub t_serial: f64,
    /// Steady-state per-step cost, `max(t_comm, t_serial)`.
    pub t_steady: f64,
    /// One-off ramp-up/drain cost of the batch, `min(t_comm, t_serial)`.
    pub t_fill_drain: f64,
    /// `steps · t_steady + t_fill_drain`.
    pub t_total: f64,
    /// `t_total / steps` — the row `repro validate` compares measured
    /// per-step medians against.
    pub t_per_step: f64,
    /// The single-step overlapped model, for comparison.
    pub t_step_overlapped: f64,
    /// The synchronous model's step time, for comparison.
    pub t_step_sync: f64,
    /// Staging-buffer depth `D` the prediction models (module doc).
    pub depth: usize,
    /// Per-step ack-gate stall, `max(0, 2τ − (D−1)·t_steady)` for `D ≥ 2`
    /// (0 for the depth-2 legacy constructor; unused at `D = 1`, where the
    /// serialization is folded into `t_steady` directly).
    pub t_gate: f64,
}

impl PipelinePrediction {
    /// Derive the batch prediction from the refined overlap decomposition.
    /// Both resource floors are cross-node maxima, not the
    /// overlap-window-binding node's terms: a node with little pack work
    /// can still gate the steady state through its transfer
    /// (`t_comm_max`), and a node with little transfer through its
    /// same-thread pack/unpack chain (`t_pack_max`/`t_unpack_max`).
    pub fn from_overlap(p: &OverlapPrediction, steps: usize) -> PipelinePrediction {
        assert!(steps >= 1, "a pipeline batch has at least one step");
        let t_serial =
            p.t_pack_max + p.t_comp_interior + p.t_unpack_max + p.t_comp_boundary;
        let t_comm = p.t_comm_max;
        let t_steady = t_comm.max(t_serial);
        let t_fill_drain = t_comm.min(t_serial);
        let t_total = steps as f64 * t_steady + t_fill_drain;
        PipelinePrediction {
            steps,
            t_comm,
            t_serial,
            t_steady,
            t_fill_drain,
            t_total,
            t_per_step: t_total / steps as f64,
            t_step_overlapped: p.t_step,
            t_step_sync: p.t_step_sync,
            depth: 2,
            t_gate: 0.0,
        }
    }

    /// Depth-aware batch prediction (module doc, "Buffer depth"). `tau` is
    /// the ack round-trip's one-way latency — `hw.tau` for the transport
    /// the run uses. `depth = 2` with a steady state longer than `2τ`
    /// reproduces [`from_overlap`] exactly.
    pub fn from_overlap_depth(
        p: &OverlapPrediction,
        steps: usize,
        depth: usize,
        tau: f64,
    ) -> PipelinePrediction {
        assert!(depth >= 1, "pipeline depth is at least 1");
        if depth == 1 {
            // Single-buffered: the ack for epoch e gates the pack of e+1,
            // so nothing amortizes across epochs — every step is the full
            // overlapped step plus the fully exposed ack round-trip.
            let t_step = p.t_step + 2.0 * tau;
            return PipelinePrediction {
                steps,
                t_steady: t_step,
                t_fill_drain: 0.0,
                t_total: steps as f64 * t_step,
                t_per_step: t_step,
                depth: 1,
                t_gate: 2.0 * tau,
                ..PipelinePrediction::from_overlap(p, steps)
            };
        }
        let base = PipelinePrediction::from_overlap(p, steps);
        let t_gate = (2.0 * tau - (depth as f64 - 1.0) * base.t_steady).max(0.0);
        let t_total = steps as f64 * (base.t_steady + t_gate) + base.t_fill_drain;
        PipelinePrediction {
            t_gate,
            t_total,
            t_per_step: t_total / steps as f64,
            depth,
            ..base
        }
    }

    /// Modeled per-step speedup over the synchronous protocol.
    pub fn speedup_vs_sync(&self) -> f64 {
        self.t_step_sync / self.t_per_step
    }

    /// Modeled per-step speedup over the single-step overlapped protocol.
    pub fn speedup_vs_overlapped(&self) -> f64 {
        self.t_step_overlapped / self.t_per_step
    }
}

/// Scan `D = 1..=4` and return the smallest depth minimizing the modeled
/// batch time, with its prediction. Ties break toward the smaller depth
/// (less staging memory, shorter fault-recovery replay window).
pub fn choose_depth(
    p: &OverlapPrediction,
    steps: usize,
    tau: f64,
) -> (usize, PipelinePrediction) {
    let mut best: Option<(usize, PipelinePrediction)> = None;
    for depth in 1..=4 {
        let pred = PipelinePrediction::from_overlap_depth(p, steps, depth, tau);
        let better = match &best {
            None => true,
            Some((_, b)) => pred.t_total < b.t_total,
        };
        if better {
            best = Some((depth, pred));
        }
    }
    best.expect("depth scan is non-empty")
}

/// Pipeline model for the heat-2D workload.
pub fn predict_heat2d_pipelined(
    grid: &HeatGrid,
    topo: &Topology,
    hw: &HwParams,
    steps: usize,
) -> PipelinePrediction {
    PipelinePrediction::from_overlap(&predict_heat2d_overlap(grid, topo, hw), steps)
}

/// Pipeline model for the 3D stencil workload.
pub fn predict_stencil3d_pipelined(
    grid: &Stencil3dGrid,
    topo: &Topology,
    hw: &HwParams,
    steps: usize,
) -> PipelinePrediction {
    PipelinePrediction::from_overlap(&predict_stencil3d_overlap(grid, topo, hw), steps)
}

/// Pipeline model for SpMV UPCv3 (the only variant with a compiled
/// exchange to pipeline).
pub fn predict_v3_pipelined(inp: &SpmvInputs, steps: usize) -> PipelinePrediction {
    PipelinePrediction::from_overlap(&predict_v3_overlap(inp), steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_amortizes_toward_steady_state() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let topo = Topology::new(2, 8);
        let p1 = predict_heat2d_pipelined(&grid, &topo, &hw, 1);
        let p8 = predict_heat2d_pipelined(&grid, &topo, &hw, 8);
        let p64 = predict_heat2d_pipelined(&grid, &topo, &hw, 64);
        // S = 1 degrades to the fully serial chain.
        assert!((p1.t_total - (p1.t_comm + p1.t_serial)).abs() < 1e-15);
        // Per-step cost decreases monotonically toward the steady state.
        assert!(p8.t_per_step <= p1.t_per_step + 1e-15);
        assert!(p64.t_per_step <= p8.t_per_step + 1e-15);
        assert!(p64.t_per_step >= p64.t_steady - 1e-15);
        // The pipelined per-step never beats the steady bound, and never
        // loses to the synchronous step.
        assert!(p64.t_per_step <= p64.t_step_sync + 1e-15);
        assert!(p64.speedup_vs_sync() >= 1.0);
    }

    #[test]
    fn deep_pipeline_at_least_matches_overlapped_model() {
        let hw = HwParams::abel();
        let grid3 = Stencil3dGrid::new(480, 480, 480, 2, 2, 2);
        let topo = Topology::new(2, 4);
        let p = predict_stencil3d_pipelined(&grid3, &topo, &hw, 32);
        // Steady state ≤ the overlapped step (which serializes pack/unpack
        // around its window each step).
        assert!(p.t_steady <= p.t_step_overlapped + 1e-15);
        assert!(p.t_step_overlapped <= p.t_step_sync + 1e-15);
    }

    #[test]
    fn depth_two_without_gate_matches_legacy_constructor() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let topo = Topology::new(2, 8);
        let p = predict_heat2d_overlap(&grid, &topo, &hw);
        let legacy = PipelinePrediction::from_overlap(&p, 16);
        // A 20k² per-thread steady state dwarfs 2τ, so the gate is zero
        // and the depth-aware model reproduces the legacy numbers exactly.
        let d2 = PipelinePrediction::from_overlap_depth(&p, 16, 2, hw.tau);
        assert_eq!(d2.t_gate, 0.0);
        assert_eq!(d2.t_total, legacy.t_total);
        assert_eq!(d2.t_per_step, legacy.t_per_step);
        assert_eq!(d2.depth, 2);
    }

    #[test]
    fn depth_one_serializes_epochs() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(4_000, 4_000, 4, 4);
        let topo = Topology::new(2, 8);
        let p = predict_heat2d_overlap(&grid, &topo, &hw);
        let d1 = PipelinePrediction::from_overlap_depth(&p, 32, 1, hw.tau);
        // No amortization: every step pays the full overlapped step plus
        // the exposed ack round-trip.
        assert_eq!(d1.t_total, 32.0 * (p.t_step + 2.0 * hw.tau));
        assert_eq!(d1.t_gate, 2.0 * hw.tau);
        assert_eq!(d1.t_fill_drain, 0.0);
        // And never beats any deeper pipeline.
        for depth in 2..=4 {
            let dd = PipelinePrediction::from_overlap_depth(&p, 32, depth, hw.tau);
            assert!(dd.t_total <= d1.t_total + 1e-15, "depth {depth}");
        }
    }

    #[test]
    fn deeper_buffers_absorb_the_ack_gate() {
        // Shrink the problem until 2τ exceeds the steady state, the
        // fine-grained regime where depth matters: the gate must be
        // positive at D = 2 and monotonically non-increasing in D. A
        // single-node topology keeps τ out of the transfer term (no
        // remote messages), so the steady state stays tiny while the ack
        // round-trip grows.
        let hw = HwParams { tau: 5.0e-4, ..HwParams::abel() };
        let grid = HeatGrid::new(64, 64, 4, 4);
        let topo = Topology::new(1, 16);
        let p = predict_heat2d_overlap(&grid, &topo, &hw);
        let preds: Vec<_> = (2..=4)
            .map(|d| PipelinePrediction::from_overlap_depth(&p, 16, d, hw.tau))
            .collect();
        assert!(preds[0].t_gate > 0.0, "regime not τ-dominated: {}", preds[0].t_gate);
        for w in preds.windows(2) {
            assert!(w[1].t_gate <= w[0].t_gate + 1e-18);
            assert!(w[1].t_total <= w[0].t_total + 1e-18);
        }
    }

    #[test]
    fn choose_depth_prefers_shallow_when_gate_is_free() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let topo = Topology::new(2, 8);
        let p = predict_heat2d_overlap(&grid, &topo, &hw);
        // Coarse-grained: the steady state dwarfs τ, every D ≥ 2 ties, so
        // the tie-break lands on D = 2 (D = 1 pays the exposed ack
        // round-trip every step, which a long batch cannot win back).
        let (d, pred) = choose_depth(&p, 64, hw.tau);
        assert_eq!(d, 2);
        assert_eq!(pred.t_total, PipelinePrediction::from_overlap(&p, 64).t_total);
        // τ-dominated (single node keeps τ out of the transfer term):
        // deeper buffers win.
        let hw_fine = HwParams { tau: 5.0e-4, ..HwParams::abel() };
        let grid_fine = HeatGrid::new(64, 64, 4, 4);
        let topo_fine = Topology::new(1, 16);
        let p_fine = predict_heat2d_overlap(&grid_fine, &topo_fine, &hw_fine);
        let (d_fine, _) = choose_depth(&p_fine, 16, hw_fine.tau);
        assert!(d_fine > 2, "τ-dominated regime should pick a deeper buffer, got {d_fine}");
    }

    #[test]
    fn v3_pipeline_wired_to_row_split() {
        let mesh = crate::mesh::tiny_mesh();
        let m = crate::matrix::Ellpack::diffusion_from_mesh(&mesh);
        let layout = crate::pgas::Layout::new(m.n, m.n.div_ceil(8), 8);
        let topo = Topology::new(2, 4);
        let a = crate::comm::Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let p = predict_v3_pipelined(&inp, 16);
        assert!(p.t_per_step > 0.0 && p.t_per_step.is_finite());
        assert!(p.t_serial > 0.0 && p.t_comm > 0.0);
        // Amortization: deeper batches never cost more per step than the
        // fully serial single-step chain, and approach the steady state.
        assert!(p.t_per_step <= p.t_comm + p.t_serial + 1e-15);
        assert!(p.t_per_step >= p.t_steady - 1e-18);
    }
}
