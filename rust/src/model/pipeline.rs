//! The multi-step pipeline performance model.
//!
//! The pipelined driver (`run_pipelined`) removes the per-step pool
//! dispatch and every global barrier: across a batch of `S` steps a thread
//! only ever waits on its own senders' publishes and its own receivers'
//! depth-2 acks, so in steady state the per-step cost is the larger of the
//! two resources that cannot be hidden behind each other — the overlappable
//! transfer and the thread's own serial chain (pack, interior, unpack,
//! boundary; pack/unpack are same-thread, see
//! [`overlap`](crate::model::OverlapPrediction)):
//!
//! ```text
//! T_steady    = max(T_transfer, T_pack + T_comp^int + T_unpack + T_comp^bnd)
//! T_total(S)  ≈ S · T_steady + T_fill/drain
//! T_fill/drain = (T_transfer + T_serial) − T_steady  = min(T_transfer, T_serial)
//! ```
//!
//! The fill/drain term is the un-overlapped remainder of the first and last
//! epochs: the pipeline needs one epoch to ramp up (the first transfer has
//! no previous interior to hide behind) and one to drain. For `S = 1` the
//! formula degrades to the fully serial `T_transfer + T_serial`; as
//! `S → ∞` the per-step cost converges to `T_steady` from above — never
//! below the overlapped single-step model's steady term, but strictly
//! below the overlapped *step* whenever both resources are non-trivial,
//! because the pipeline also hides each epoch's residual wait behind the
//! next epoch's work.

use super::{
    predict_heat2d_overlap, predict_stencil3d_overlap, predict_v3_overlap, HeatGrid,
    OverlapPrediction, SpmvInputs,
};
use crate::machine::HwParams;
use crate::pgas::Topology;
use crate::stencil3d::Stencil3dGrid;

/// Output of the pipeline model for a batch of `steps` time steps.
#[derive(Debug, Clone, Copy)]
pub struct PipelinePrediction {
    /// Batch size the prediction was evaluated for.
    pub steps: usize,
    /// The overlappable transfer term per step (largest across all nodes).
    pub t_comm: f64,
    /// The same-thread serial chain per step: pack + interior + unpack +
    /// boundary, with pack/unpack taken at their cross-node maxima.
    pub t_serial: f64,
    /// Steady-state per-step cost, `max(t_comm, t_serial)`.
    pub t_steady: f64,
    /// One-off ramp-up/drain cost of the batch, `min(t_comm, t_serial)`.
    pub t_fill_drain: f64,
    /// `steps · t_steady + t_fill_drain`.
    pub t_total: f64,
    /// `t_total / steps` — the row `repro validate` compares measured
    /// per-step medians against.
    pub t_per_step: f64,
    /// The single-step overlapped model, for comparison.
    pub t_step_overlapped: f64,
    /// The synchronous model's step time, for comparison.
    pub t_step_sync: f64,
}

impl PipelinePrediction {
    /// Derive the batch prediction from the refined overlap decomposition.
    /// Both resource floors are cross-node maxima, not the
    /// overlap-window-binding node's terms: a node with little pack work
    /// can still gate the steady state through its transfer
    /// (`t_comm_max`), and a node with little transfer through its
    /// same-thread pack/unpack chain (`t_pack_max`/`t_unpack_max`).
    pub fn from_overlap(p: &OverlapPrediction, steps: usize) -> PipelinePrediction {
        assert!(steps >= 1, "a pipeline batch has at least one step");
        let t_serial =
            p.t_pack_max + p.t_comp_interior + p.t_unpack_max + p.t_comp_boundary;
        let t_comm = p.t_comm_max;
        let t_steady = t_comm.max(t_serial);
        let t_fill_drain = t_comm.min(t_serial);
        let t_total = steps as f64 * t_steady + t_fill_drain;
        PipelinePrediction {
            steps,
            t_comm,
            t_serial,
            t_steady,
            t_fill_drain,
            t_total,
            t_per_step: t_total / steps as f64,
            t_step_overlapped: p.t_step,
            t_step_sync: p.t_step_sync,
        }
    }

    /// Modeled per-step speedup over the synchronous protocol.
    pub fn speedup_vs_sync(&self) -> f64 {
        self.t_step_sync / self.t_per_step
    }

    /// Modeled per-step speedup over the single-step overlapped protocol.
    pub fn speedup_vs_overlapped(&self) -> f64 {
        self.t_step_overlapped / self.t_per_step
    }
}

/// Pipeline model for the heat-2D workload.
pub fn predict_heat2d_pipelined(
    grid: &HeatGrid,
    topo: &Topology,
    hw: &HwParams,
    steps: usize,
) -> PipelinePrediction {
    PipelinePrediction::from_overlap(&predict_heat2d_overlap(grid, topo, hw), steps)
}

/// Pipeline model for the 3D stencil workload.
pub fn predict_stencil3d_pipelined(
    grid: &Stencil3dGrid,
    topo: &Topology,
    hw: &HwParams,
    steps: usize,
) -> PipelinePrediction {
    PipelinePrediction::from_overlap(&predict_stencil3d_overlap(grid, topo, hw), steps)
}

/// Pipeline model for SpMV UPCv3 (the only variant with a compiled
/// exchange to pipeline).
pub fn predict_v3_pipelined(inp: &SpmvInputs, steps: usize) -> PipelinePrediction {
    PipelinePrediction::from_overlap(&predict_v3_overlap(inp), steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_amortizes_toward_steady_state() {
        let hw = HwParams::abel();
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let topo = Topology::new(2, 8);
        let p1 = predict_heat2d_pipelined(&grid, &topo, &hw, 1);
        let p8 = predict_heat2d_pipelined(&grid, &topo, &hw, 8);
        let p64 = predict_heat2d_pipelined(&grid, &topo, &hw, 64);
        // S = 1 degrades to the fully serial chain.
        assert!((p1.t_total - (p1.t_comm + p1.t_serial)).abs() < 1e-15);
        // Per-step cost decreases monotonically toward the steady state.
        assert!(p8.t_per_step <= p1.t_per_step + 1e-15);
        assert!(p64.t_per_step <= p8.t_per_step + 1e-15);
        assert!(p64.t_per_step >= p64.t_steady - 1e-15);
        // The pipelined per-step never beats the steady bound, and never
        // loses to the synchronous step.
        assert!(p64.t_per_step <= p64.t_step_sync + 1e-15);
        assert!(p64.speedup_vs_sync() >= 1.0);
    }

    #[test]
    fn deep_pipeline_at_least_matches_overlapped_model() {
        let hw = HwParams::abel();
        let grid3 = Stencil3dGrid::new(480, 480, 480, 2, 2, 2);
        let topo = Topology::new(2, 4);
        let p = predict_stencil3d_pipelined(&grid3, &topo, &hw, 32);
        // Steady state ≤ the overlapped step (which serializes pack/unpack
        // around its window each step).
        assert!(p.t_steady <= p.t_step_overlapped + 1e-15);
        assert!(p.t_step_overlapped <= p.t_step_sync + 1e-15);
    }

    #[test]
    fn v3_pipeline_wired_to_row_split() {
        let mesh = crate::mesh::tiny_mesh();
        let m = crate::matrix::Ellpack::diffusion_from_mesh(&mesh);
        let layout = crate::pgas::Layout::new(m.n, m.n.div_ceil(8), 8);
        let topo = Topology::new(2, 4);
        let a = crate::comm::Analysis::build(&m.j, m.r_nz, layout, topo, usize::MAX);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let p = predict_v3_pipelined(&inp, 16);
        assert!(p.t_per_step > 0.0 && p.t_per_step.is_finite());
        assert!(p.t_serial > 0.0 && p.t_comm > 0.0);
        // Amortization: deeper batches never cost more per step than the
        // fully serial single-step chain, and approach the steady state.
        assert!(p.t_per_step <= p.t_comm + p.t_serial + 1e-15);
        assert!(p.t_per_step >= p.t_steady - 1e-18);
    }
}
