//! 2D heat-equation performance model — the paper's §8.2, eqs. (19)–(22).
//!
//! The solver (Listing 7/8) arranges `THREADS = mprocs × nprocs` threads in a
//! 2D grid; each owns an `m × n` subdomain *including* a one-cell halo, so
//! the interior is `(m−2) × (n−2)`. Halo exchange: vertical neighbours are
//! contiguous (`upc_memget` directly), horizontal neighbours need
//! pack/unpack through scratch arrays.

use crate::machine::{HwParams, SIZEOF_DOUBLE};
use crate::pgas::Topology;

/// Geometry of a heat-2D run (see [`crate::heat2d`] for the solver itself).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeatGrid {
    /// Global mesh dimensions (paper's `M × N`, e.g. 20000 × 20000).
    pub m_glob: usize,
    pub n_glob: usize,
    /// Thread-grid partitioning (paper's `mprocs × nprocs`).
    pub mprocs: usize,
    pub nprocs: usize,
}

impl HeatGrid {
    pub fn new(m_glob: usize, n_glob: usize, mprocs: usize, nprocs: usize) -> HeatGrid {
        assert!(m_glob % mprocs == 0 && n_glob % nprocs == 0, "uneven partitioning");
        HeatGrid { m_glob, n_glob, mprocs, nprocs }
    }

    pub fn threads(&self) -> usize {
        self.mprocs * self.nprocs
    }

    /// Per-thread subdomain dims including the halo layer (paper's `m`, `n`).
    pub fn subdomain(&self) -> (usize, usize) {
        (self.m_glob / self.mprocs + 2, self.n_glob / self.nprocs + 2)
    }

    /// Grid coordinates of a thread (paper: `iproc = t / nprocs`,
    /// `kproc = t % nprocs`).
    pub fn coords(&self, t: usize) -> (usize, usize) {
        (t / self.nprocs, t % self.nprocs)
    }

    pub fn rank(&self, iproc: usize, kproc: usize) -> usize {
        iproc * self.nprocs + kproc
    }

    /// The ≤ 4 neighbours of thread `t`: (neighbour id, message length in
    /// doubles, horizontal?).
    pub fn neighbours(&self, t: usize) -> Vec<(usize, usize, bool)> {
        let (ip, kp) = self.coords(t);
        let (m, n) = self.subdomain();
        let mut out = Vec::with_capacity(4);
        if ip > 0 {
            out.push((self.rank(ip - 1, kp), n - 2, false));
        }
        if ip < self.mprocs - 1 {
            out.push((self.rank(ip + 1, kp), n - 2, false));
        }
        if kp > 0 {
            out.push((self.rank(ip, kp - 1), m - 2, true));
        }
        if kp < self.nprocs - 1 {
            out.push((self.rank(ip, kp + 1), m - 2, true));
        }
        out
    }
}

/// Output of the §8.2 model.
#[derive(Debug, Clone)]
pub struct Heat2dPrediction {
    /// Eq. (21): halo-exchange time per step.
    pub t_halo: f64,
    /// Eq. (22): computation time per step.
    pub t_comp: f64,
    /// Per-thread pack (= unpack) times, eq. (19).
    pub t_pack: Vec<f64>,
    /// Per-node memget times, eq. (20).
    pub t_memget_node: Vec<f64>,
}

/// Evaluate eqs. (19)–(22) for one time step.
pub fn predict_heat2d(grid: &HeatGrid, topo: &Topology, hw: &HwParams) -> Heat2dPrediction {
    assert_eq!(topo.threads(), grid.threads());
    const D: f64 = SIZEOF_DOUBLE as f64;
    let w = hw.w_thread_private;
    let cl = hw.cache_line as f64;
    let threads = grid.threads();

    // Eq. (19): per-thread pack/unpack — horizontal messages only. Charged
    // at the measured gather/scatter bandwidth `w_pack` (equal to the
    // STREAM figure on Abel and on pre-pack-probe calibrations, which
    // recovers the paper's term verbatim).
    let mut t_pack = vec![0.0f64; threads];
    for (t, tp) in t_pack.iter_mut().enumerate() {
        let s_horiz: usize = grid
            .neighbours(t)
            .iter()
            .filter(|&&(_, _, horiz)| horiz)
            .map(|&(_, len, _)| len)
            .sum();
        *tp = hw.t_pack_stream(s_horiz as f64 * (D + cl));
    }

    // Eq. (20): per-node memget — local transfers concurrent (max), remote
    // serialized on the NIC (sum), each remote message paying τ.
    let mut t_memget_node = vec![0.0f64; topo.nodes];
    for node in 0..topo.nodes {
        let mut local_max = 0.0f64;
        let mut remote_sum = 0.0f64;
        for t in topo.threads_of_node(node) {
            let mut s_local = 0usize;
            let mut s_remote = 0usize;
            let mut c_remote = 0usize;
            for (peer, len, _) in grid.neighbours(t) {
                if topo.same_node(t, peer) {
                    s_local += len;
                } else {
                    s_remote += len;
                    c_remote += 1;
                }
            }
            local_max = local_max.max(2.0 * s_local as f64 * D / w);
            remote_sum += c_remote as f64 * hw.tau + s_remote as f64 * D / hw.w_node_remote;
        }
        t_memget_node[node] = local_max + remote_sum;
    }

    // Eq. (21): max over nodes of (max pack + memget + max unpack); pack and
    // unpack are modeled identical.
    let mut t_halo = 0.0f64;
    for node in 0..topo.nodes {
        let pack_max = topo
            .threads_of_node(node)
            .map(|t| t_pack[t])
            .fold(0.0, f64::max);
        t_halo = t_halo.max(pack_max + t_memget_node[node] + pack_max);
    }

    // Eq. (22): 3 streams (read phi twice effectively + write phin → the
    // paper counts 3·(m−2)·(n−2)·sizeof(double) of memory traffic).
    let (m, n) = grid.subdomain();
    let t_comp = 3.0 * ((m - 2) * (n - 2)) as f64 * D / w;

    Heat2dPrediction { t_halo, t_comp, t_pack, t_memget_node }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table5_comp_20000_16threads() {
        // Table 5, mesh 20000², 16 threads (4×4): T_comp predicted 122.07 s
        // for 1000 steps.
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let topo = Topology::new(1, 16);
        let p = predict_heat2d(&grid, &topo, &HwParams::abel());
        let total = p.t_comp * 1000.0;
        // 128.0 vs the paper's 122.07: a 4.9 % gap traceable to the paper's
        // GB/GiB convention for the 75 GB/s STREAM figure; we accept ±6 %.
        assert!((total - 122.07).abs() / 122.07 < 0.06, "T_comp 1000 steps = {total}");
    }

    #[test]
    fn paper_table5_comp_40000_512threads() {
        // Table 5, mesh 40000², 512 threads (16×32): predicted 15.26 s.
        let grid = HeatGrid::new(40_000, 40_000, 16, 32);
        let topo = Topology::new(32, 16);
        let p = predict_heat2d(&grid, &topo, &HwParams::abel());
        let total = p.t_comp * 1000.0;
        assert!((total - 15.26).abs() / 15.26 < 0.06, "T_comp 1000 steps = {total}");
    }

    #[test]
    fn paper_table5_halo_magnitude() {
        // Table 5, 20000², 16 threads: T_halo predicted 0.33 s per 1000
        // steps. Our eq. implementation should land within ~15 %.
        let grid = HeatGrid::new(20_000, 20_000, 4, 4);
        let topo = Topology::new(1, 16);
        let p = predict_heat2d(&grid, &topo, &HwParams::abel());
        let total = p.t_halo * 1000.0;
        assert!((total - 0.33).abs() / 0.33 < 0.35, "T_halo 1000 steps = {total}");
    }

    #[test]
    fn neighbours_topology() {
        let grid = HeatGrid::new(100, 100, 2, 2);
        // Thread 0 at (0,0): neighbours down (t2) and right (t1).
        let nb = grid.neighbours(0);
        assert_eq!(nb.len(), 2);
        // subdomain 52x52 incl. halo -> message length 50
        assert!(nb.contains(&(2, 50, false)) && nb.contains(&(1, 50, true)),
            "{nb:?}");
        // Interior thread in a 3×3 grid has 4 neighbours.
        let g9 = HeatGrid::new(90, 90, 3, 3);
        assert_eq!(g9.neighbours(4).len(), 4);
    }

    #[test]
    fn halo_shrinks_with_more_nodes_held_mesh() {
        let hw = HwParams::abel();
        let g16 = HeatGrid::new(20_000, 20_000, 4, 4);
        let g256 = HeatGrid::new(20_000, 20_000, 16, 16);
        let h16 = predict_heat2d(&g16, &Topology::new(1, 16), &hw).t_halo;
        let h256 = predict_heat2d(&g256, &Topology::new(16, 16), &hw).t_halo;
        // Messages shrink with subdomain size → halo time decreases
        // (Table 5 shows 0.33 → 0.13).
        assert!(h256 < h16, "{h256} !< {h16}");
    }
}
