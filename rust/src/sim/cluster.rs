//! Analytic per-thread-clock execution of one SpMV iteration per variant.

use crate::machine::{HwParams, NaiveOverheads, PTR_ACCESSES_PER_ROW, SIZEOF_DOUBLE, SIZEOF_INT};
use crate::model::SpmvInputs;
use crate::spmv::Variant;

/// Second-order machine behaviour the closed-form models ignore. All values
/// are derived from the four §6.2 constants unless overridden.
#[derive(Debug, Clone, Copy)]
pub struct SimParams {
    /// Fixed wire/software latency part of an individual remote op.
    pub tau_wire: f64,
    /// Additional latency per extra concurrently-communicating thread on
    /// the node (`τ_eff(c) = τ_wire + (c−1)·τ_slope`).
    pub tau_slope: f64,
    /// NIC occupancy per individual remote op (message-rate bound,
    /// ~2.2 M msg/s for FDR-generation HCAs).
    pub tau_occ: f64,
    /// Software overhead per consolidated message (pack/put call path).
    pub c_msg: f64,
    /// Per-block screening cost in UPCv2's needed-block loop.
    pub c_screen: f64,
    /// Extra bytes fetched per cache-missing `x` access (a line minus the
    /// 8 useful bytes).
    pub extra_miss_bytes: f64,
    /// LLC reuse window (elements) used when the analysis was built.
    pub cache_window: usize,
}

impl SimParams {
    /// Calibrate from the hardware constants: `τ_eff(8) = τ` (the Listing-6
    /// benchmark ran 8 communicating threads per node).
    pub fn from_hw(hw: &HwParams) -> SimParams {
        SimParams {
            tau_wire: 0.35 * hw.tau,
            tau_slope: 0.65 * hw.tau / 7.0,
            tau_occ: 0.45e-6,
            c_msg: 0.5e-6,
            c_screen: 1.0e-9,
            extra_miss_bytes: (hw.cache_line - SIZEOF_DOUBLE) as f64,
            cache_window: super::DEFAULT_CACHE_WINDOW,
        }
    }

    /// Effective individual-remote-op latency when `c` threads on the node
    /// communicate concurrently.
    #[inline]
    pub fn tau_eff(&self, c: usize) -> f64 {
        self.tau_wire + (c.saturating_sub(1)) as f64 * self.tau_slope
    }
}

/// "Measured" times for one SpMV iteration.
#[derive(Debug, Clone)]
pub struct SimMeasurement {
    /// Wall-clock of the iteration (slowest node/thread, after barrier).
    pub total: f64,
    /// Per-thread compute time (incl. cache-imperfection extra).
    pub t_comp: Vec<f64>,
    /// Per-thread communication/overhead time attributed to the thread.
    pub t_comm: Vec<f64>,
    /// Per-thread pack time (v3 only; zeros otherwise) — Figure 1.
    pub t_pack: Vec<f64>,
    /// Per-thread unpack time (v3 only) — Figure 1.
    pub t_unpack: Vec<f64>,
}

/// The simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterSim {
    pub hw: HwParams,
    pub params: SimParams,
    pub naive: NaiveOverheads,
}

impl ClusterSim {
    pub fn new(hw: HwParams) -> ClusterSim {
        ClusterSim { hw, params: SimParams::from_hw(&hw), naive: NaiveOverheads::calibrated() }
    }

    /// Simulate one SpMV iteration of `variant`.
    pub fn spmv_iteration(&self, variant: Variant, inp: &SpmvInputs) -> SimMeasurement {
        match variant {
            Variant::Naive => self.sim_v1(inp, true),
            Variant::V1 => self.sim_v1(inp, false),
            Variant::V2 => self.sim_v2(inp),
            Variant::V3 => self.sim_v3(inp),
        }
    }

    /// Actual per-thread compute time: exact owned-row count (the models
    /// round the tail block up) at eq. (6) traffic plus the cache-miss
    /// correction for far accesses.
    fn comp_time(&self, inp: &SpmvInputs, t: usize) -> f64 {
        let rows = inp.layout.nelems_of_thread(t) as f64;
        let d_min = (inp.r_nz * (SIZEOF_DOUBLE + SIZEOF_INT) + 3 * SIZEOF_DOUBLE) as f64;
        let tt = &inp.analysis.per_thread[t];
        let extra = tt.far_accesses as f64 * self.params.extra_miss_bytes;
        (rows * d_min + extra) / self.hw.w_thread_private
    }

    /// UPCv1 (and naive): element-wise accesses; individual remote ops pay
    /// the concurrency-dependent τ and are additionally bounded by the NIC
    /// message rate per node.
    fn sim_v1(&self, inp: &SpmvInputs, naive: bool) -> SimMeasurement {
        let threads = inp.layout.threads;
        let topo = &inp.topo;
        let mut t_comp = vec![0.0; threads];
        let mut t_comm = vec![0.0; threads];
        let mut total = 0.0f64;
        for node in 0..topo.nodes {
            let communicating = topo
                .threads_of_node(node)
                .filter(|&t| inp.analysis.per_thread[t].c_remote_indv > 0)
                .count();
            let tau_eff = self.params.tau_eff(communicating);
            let mut node_end = 0.0f64;
            let mut nic_ops = 0u64;
            for t in topo.threads_of_node(node) {
                let tt = &inp.analysis.per_thread[t];
                let mut comp = self.comp_time(inp, t);
                if naive {
                    // Every thread walks the whole iteration space and pays
                    // the pointer-to-shared field updates on its own rows.
                    comp += inp.layout.n as f64 * self.naive.c_forall
                        + inp.layout.nelems_of_thread(t) as f64
                            * PTR_ACCESSES_PER_ROW
                            * self.naive.c_ptr;
                }
                let comm = tt.c_local_indv as f64 * self.hw.t_indv_local()
                    + tt.c_remote_indv as f64 * tau_eff;
                nic_ops += tt.c_remote_indv;
                t_comp[t] = comp;
                t_comm[t] = comm;
                node_end = node_end.max(comp + comm);
            }
            // NIC message-rate floor for the node.
            let nic_floor = nic_ops as f64 * self.params.tau_occ;
            total = total.max(node_end.max(nic_floor));
        }
        SimMeasurement { total, t_comp, t_comm, t_pack: vec![0.0; threads], t_unpack: vec![0.0; threads] }
    }

    /// Count, per node, how many needed-block transfers *serve* requests
    /// from other nodes (outbound pressure the v2 model ignores).
    fn v2_outbound_blocks(&self, inp: &SpmvInputs) -> Vec<u64> {
        let a = inp.analysis;
        let mut outbound = vec![0u64; inp.topo.nodes];
        for t in 0..inp.layout.threads {
            let tn = inp.topo.node_of_thread(t);
            for b in 0..inp.layout.nblks() {
                if a.block_needed(t, b) {
                    let on = inp.topo.node_of_thread(inp.layout.owner_of_block(b));
                    if on != tn {
                        outbound[on] += 1;
                    }
                }
            }
        }
        outbound
    }

    /// UPCv2: block-wise `upc_memget` of every needed block.
    fn sim_v2(&self, inp: &SpmvInputs) -> SimMeasurement {
        let threads = inp.layout.threads;
        let topo = &inp.topo;
        let bs_bytes = (inp.layout.block_size * SIZEOF_DOUBLE) as f64;
        let outbound = self.v2_outbound_blocks(inp);
        let mut t_comp = vec![0.0; threads];
        let mut t_comm = vec![0.0; threads];
        let mut total = 0.0f64;
        for node in 0..topo.nodes {
            let communicating = topo
                .threads_of_node(node)
                .filter(|&t| inp.analysis.per_thread[t].b_remote > 0)
                .count();
            let tau_eff = self.params.tau_eff(communicating);
            let mut local_max = 0.0f64;
            let mut inbound = 0.0f64;
            let mut comp_max = 0.0f64;
            for t in topo.threads_of_node(node) {
                let tt = &inp.analysis.per_thread[t];
                let screen = inp.layout.nblks() as f64 * self.params.c_screen;
                let local = tt.b_local as f64 * 2.0 * bs_bytes / self.hw.w_thread_private;
                inbound += tt.b_remote as f64 * (tau_eff + bs_bytes / self.hw.w_node_remote);
                let comp = self.comp_time(inp, t);
                t_comp[t] = comp;
                t_comm[t] = screen + local; // thread-attributed part
                local_max = local_max.max(screen + local);
                comp_max = comp_max.max(comp);
            }
            // The node's NIC also serves other nodes' memgets.
            let serve = outbound[node] as f64 * bs_bytes / self.hw.w_node_remote;
            let nic_busy = inbound + serve;
            total = total.max(local_max + nic_busy + comp_max);
        }
        SimMeasurement { total, t_comp, t_comm, t_pack: vec![0.0; threads], t_unpack: vec![0.0; threads] }
    }

    /// UPCv3: pack → `upc_memput` → barrier → copy-own + unpack → compute.
    fn sim_v3(&self, inp: &SpmvInputs) -> SimMeasurement {
        let threads = inp.layout.threads;
        let topo = &inp.topo;
        const D: f64 = SIZEOF_DOUBLE as f64;
        const I: f64 = SIZEOF_INT as f64;
        let w = self.hw.w_thread_private;
        let cl = self.hw.cache_line as f64;
        let a = inp.analysis;

        // Inbound bulk volume per node (other nodes' puts landing here).
        let mut inbound_bytes = vec![0.0f64; topo.nodes];
        for t in 0..threads {
            let tt = &a.per_thread[t];
            let dst_node_bytes = tt.s_remote_in as f64 * D;
            inbound_bytes[topo.node_of_thread(t)] += dst_node_bytes;
        }

        let mut t_pack = vec![0.0; threads];
        let mut t_unpack = vec![0.0; threads];
        let mut t_comp = vec![0.0; threads];
        let mut t_comm = vec![0.0; threads];

        // Phase 1: pack + memput, ends at a barrier.
        let mut phase1 = 0.0f64;
        for node in 0..topo.nodes {
            let communicating = topo
                .threads_of_node(node)
                .filter(|&t| a.per_thread[t].c_remote_out > 0)
                .count();
            let tau_eff = self.params.tau_eff(communicating);
            let mut pack_max = 0.0f64;
            let mut local_put_max = 0.0f64;
            let mut remote_put = 0.0f64;
            for t in topo.threads_of_node(node) {
                let tt = &a.per_thread[t];
                let msgs = (tt.c_local_out + tt.c_remote_out) as f64;
                let pack = (tt.s_local_out + tt.s_remote_out) as f64 * (2.0 * D + I) / w
                    + msgs * self.params.c_msg;
                t_pack[t] = pack;
                pack_max = pack_max.max(pack);
                local_put_max = local_put_max.max(2.0 * tt.s_local_out as f64 * D / w);
                remote_put += tt.c_remote_out as f64 * tau_eff
                    + tt.s_remote_out as f64 * D / self.hw.w_node_remote;
            }
            // NIC also receives other nodes' puts.
            let nic_busy = remote_put + inbound_bytes[node] / self.hw.w_node_remote;
            for t in topo.threads_of_node(node) {
                t_comm[t] = local_put_max + nic_busy;
            }
            phase1 = phase1.max(pack_max + local_put_max + nic_busy);
        }

        // Phase 2 (after barrier): copy own blocks, unpack, compute.
        let mut phase2 = 0.0f64;
        for t in 0..threads {
            let tt = &a.per_thread[t];
            let own_bytes = inp.layout.nelems_of_thread(t) as f64 * D;
            let copy = 2.0 * own_bytes / w;
            let unpack = (tt.s_local_in + tt.s_remote_in) as f64 * (D + I + cl) / w
                + (tt.c_local_in + tt.c_remote_in) as f64 * self.params.c_msg;
            let comp = self.comp_time(inp, t);
            t_unpack[t] = unpack;
            t_comp[t] = comp;
            t_comm[t] += copy;
            phase2 = phase2.max(copy + unpack + comp);
        }

        SimMeasurement { total: phase1 + phase2, t_comp, t_comm, t_pack, t_unpack }
    }
}

/// Convenience used by tests and harness: simulate `iters` iterations (the
/// traffic is identical each step, as in the paper's time loop).
#[allow(dead_code)]
pub fn simulate_iters(sim: &ClusterSim, variant: Variant, inp: &SpmvInputs, iters: usize) -> f64 {
    sim.spmv_iteration(variant, inp).total * iters as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Analysis;
    use crate::matrix::Ellpack;
    use crate::pgas::{Layout, Topology};

    fn setup(nodes: usize, tpn: usize, bs: usize) -> (Ellpack, Layout, Topology, Analysis) {
        let mesh = crate::mesh::tiny_mesh();
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, bs, nodes * tpn);
        let topo = Topology::new(nodes, tpn);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, crate::sim::DEFAULT_CACHE_WINDOW);
        (m, layout, topo, a)
    }

    #[test]
    fn tau_eff_calibration() {
        let p = SimParams::from_hw(&HwParams::abel());
        assert!((p.tau_eff(8) - 3.4e-6).abs() < 1e-12);
        assert!(p.tau_eff(1) < 3.4e-6);
        assert!(p.tau_eff(16) > 3.4e-6);
    }

    #[test]
    fn variant_ordering_multinode() {
        // Paper regime: BLOCKSIZE ≫ stencil span, several blocks/thread.
        let mesh = crate::mesh::TetMesh::generate(
            &crate::mesh::TetGridSpec::ventricle(100_000, 3),
        );
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, m.n / 64, 16);
        let topo = Topology::new(4, 4);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, crate::sim::DEFAULT_CACHE_WINDOW);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let sim = ClusterSim::new(HwParams::abel());
        let naive = sim.spmv_iteration(Variant::Naive, &inp).total;
        let v1 = sim.spmv_iteration(Variant::V1, &inp).total;
        let v2 = sim.spmv_iteration(Variant::V2, &inp).total;
        let v3 = sim.spmv_iteration(Variant::V3, &inp).total;
        assert!(naive > v1, "naive {naive} vs v1 {v1}");
        assert!(v1 > v2, "v1 {v1} vs v2 {v2} (multi-node fine-grained collapse)");
        assert!(v2 > v3, "v2 {v2} vs v3 {v3}");
    }

    #[test]
    fn single_node_v1_beats_v2_like_table3() {
        // Needs the paper's BLOCKSIZE ≫ stencil-bandwidth regime (see the
        // twin test in model::spmv).
        let mesh = crate::mesh::TetMesh::generate(
            &crate::mesh::TetGridSpec::ventricle(100_000, 3),
        );
        let m = Ellpack::diffusion_from_mesh(&mesh);
        let layout = Layout::new(m.n, m.n / 16, 16); // 1 block/thread, paper Table-4 style
        let topo = Topology::single_node(16);
        let a = Analysis::build(&m.j, m.r_nz, layout, topo, crate::sim::DEFAULT_CACHE_WINDOW);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let sim = ClusterSim::new(HwParams::abel());
        let v1 = sim.spmv_iteration(Variant::V1, &inp).total;
        let v2 = sim.spmv_iteration(Variant::V2, &inp).total;
        assert!(v1 < v2, "single node: v1 {v1} should beat v2 {v2}");
    }

    #[test]
    fn sim_close_to_model_for_v3() {
        // For the bulk variants the sim adds only second-order terms; it
        // should land within ~50 % of the closed-form model (the paper's
        // Table 4 shows similar agreement).
        let (m, layout, topo, a) = setup(2, 8, 256);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let sim = ClusterSim::new(HwParams::abel());
        let actual = sim.spmv_iteration(Variant::V3, &inp).total;
        let predicted = crate::model::predict_v3(&inp).total;
        let ratio = actual / predicted;
        assert!((0.5..2.0).contains(&ratio), "v3 actual/predicted = {ratio}");
    }

    #[test]
    fn figure1_series_nonzero_for_v3() {
        // bs=64 keeps nblks ≥ threads so every thread owns rows.
        let (m, layout, topo, a) = setup(2, 8, 64);
        let inp = SpmvInputs { layout, topo, hw: HwParams::abel(), r_nz: m.r_nz, analysis: &a };
        let sim = ClusterSim::new(HwParams::abel());
        let meas = sim.spmv_iteration(Variant::V3, &inp);
        assert!(meas.t_pack.iter().any(|&x| x > 0.0));
        assert!(meas.t_unpack.iter().any(|&x| x > 0.0));
        assert!(meas.t_comp.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn random_ordering_slows_compute() {
        let mesh = crate::mesh::tiny_mesh();
        let shuffled = crate::mesh::Ordering::Random.apply(&mesh);
        let hw = HwParams::abel();
        let sim = ClusterSim::new(hw);
        let mk = |mesh: &crate::mesh::TetMesh| {
            let m = Ellpack::diffusion_from_mesh(mesh);
            let layout = Layout::new(m.n, 128, 8);
            let topo = Topology::new(2, 4);
            // Tiny window so locality differences show up at test scale.
            let a = Analysis::build(&m.j, m.r_nz, layout, topo, 500);
            let inp = SpmvInputs { layout, topo, hw, r_nz: m.r_nz, analysis: &a };
            sim.spmv_iteration(Variant::V3, &inp).t_comp.iter().sum::<f64>()
        };
        let natural = mk(&mesh);
        let random = mk(&shuffled);
        assert!(random > natural * 1.2, "random {random} vs natural {natural}");
    }
}
