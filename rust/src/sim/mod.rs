//! The simulated cluster — produces the "measured" side of every
//! actual-vs-predicted comparison.
//!
//! The closed-form models of [`crate::model`] idealize several effects the
//! real Abel cluster exhibits in the paper's measurements. This simulator
//! *executes* the per-thread traffic of an [`Analysis`](crate::comm::Analysis)
//! against the same four hardware constants, adding exactly the effects the
//! paper discusses when explaining model deviations (§6.4):
//!
//! 1. **Concurrency-dependent τ** — the paper measured τ = 3.4 µs with 8
//!    threads/node communicating simultaneously and notes the effective τ is
//!    smaller with fewer communicating threads (and implicitly larger with
//!    more). We model `τ_eff(c) = τ_wire + (c−1)·τ_slope`, calibrated so
//!    `τ_eff(8) = τ`.
//! 2. **NIC message-rate floor** — a node's HCA processes individual remote
//!    operations at a finite rate; massive fine-grained traffic (UPCv1
//!    multi-node) is bounded by `Σ ops · τ_occ` regardless of per-thread
//!    latency hiding. This produces UPCv1's measured collapse (Table 3).
//! 3. **Inbound/outbound NIC sharing** — bulk transfers occupy both the
//!    requesting and the serving node's interconnect; the models charge only
//!    one side.
//! 4. **Cache-imperfect compute** — eq. (6) assumes perfect last-level-cache
//!    reuse of `x`; accesses farther than a reuse window pay an extra cache
//!    line. Negligible for the paper's "properly ordered" meshes, large for
//!    the random-ordering ablation.
//! 5. **Actual (not block-rounded) row counts and software per-message
//!    overheads.**

mod cluster;

pub use cluster::{ClusterSim, SimMeasurement, SimParams};

/// Default LLC reuse window, in elements of `x`: 20 MB Sandy-Bridge LLC
/// shared by 16 threads → 1.25 MB/thread → 163 840 doubles.
pub const DEFAULT_CACHE_WINDOW: usize = 163_840;
