//! mdlite: a dynamic-pattern particle/field workload for the versioned
//! plan lifecycle.
//!
//! The grid solvers compile their exchange plan once; real irregular
//! applications (molecular dynamics, particle-in-cell) re-derive theirs
//! every few steps as particles drift across the domain decomposition.
//! mdlite is the smallest workload with that property that can still be
//! validated **bitwise**:
//!
//! * Particles move on closed-form integer trajectories over a torus
//!   (`pos(s) = pos0 + s·vel mod extent`, in fixed-point cell units), so
//!   every thread/rank computes every particle's cell at any step with
//!   plain integer arithmetic — the particles need no communication and no
//!   ownership migration. The *field* is the only distributed state; the
//!   *pattern* still changes every step.
//! * A per-cell field φ lives row-band-partitioned under a block-cyclic
//!   [`Layout`]. Each step, every **occupied** owned cell relaxes toward
//!   its 8 torus neighbors plus an occupancy source term; remote neighbor
//!   values arrive through a condensed gather [`CommPlan`] compiled from
//!   the occupied cells' halo.
//! * Every `rebuild_every` (K) steps the plan is rebuilt for the current
//!   particle positions. [`Lifecycle::FullRecompile`] recompiles from
//!   scratch (the oracle); [`Lifecycle::Incremental`] diffs the per-pair
//!   needs against its bookkeeping, builds a [`PlanDelta`], and patches
//!   the live plan in O(|delta|) — asserting the patched plan is
//!   fingerprint-identical to the oracle's and extending the chain
//!   fingerprint `fp(gen N) = hash(fp(gen N−1), delta)`.
//!
//! Between rebuilds the plan is deliberately stale: cells that became
//! occupied since the last rebuild read whatever their neighbor slots in
//! the per-thread workspace last held. That is *deterministic* — the
//! workspace has an identical write history in every arm (zero-initialized,
//! then only own-band copies and plan scatters) — so staleness does not
//! break bitwise equality, it is part of the workload being modeled
//! (the rebuild-amortization tradeoff in [`crate::model`]).
//!
//! Three arms execute the same schedule: in-process sequential, in-process
//! parallel (scoped threads, disjoint bands), and multi-rank sockets where
//! rank 0 ships each [`PlanDelta`] over the wire as a `KIND_DELTA` frame
//! and peers apply it locally. All three must agree bitwise on the final
//! field.

use crate::comm::{chain_fingerprint, CommPlan, ExchangePlan, GatherPatch, PlanDelta};
use crate::engine::Engine;
use crate::pgas::Layout;
use crate::transport::{loopback_mesh, MeshStreams, SocketTransport, Transport};
use crate::util::rng::Rng;
use crate::util::Fnv64;
use std::collections::BTreeMap;
use std::time::Duration;

/// How the plan advances across rebuild boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// Recompile the plan from scratch at every rebuild (the oracle).
    FullRecompile,
    /// Diff the needs, build a [`PlanDelta`], patch the live plan in
    /// O(|delta|), and verify it is fingerprint-identical to the oracle.
    Incremental,
}

impl Lifecycle {
    pub fn name(self) -> &'static str {
        match self {
            Lifecycle::FullRecompile => "full",
            Lifecycle::Incremental => "incremental",
        }
    }

    pub fn parse(s: &str) -> Option<Lifecycle> {
        match s.to_ascii_lowercase().as_str() {
            "full" | "oracle" => Some(Lifecycle::FullRecompile),
            "incr" | "incremental" | "delta" => Some(Lifecycle::Incremental),
            _ => None,
        }
    }
}

/// Fixed-point sub-cell resolution: particle positions advance in units of
/// 1/8 cell, so a particle typically stays in its cell for a few steps and
/// the gather pattern drifts rather than teleports.
const RES: i64 = 8;

/// The 8-neighbor offsets in the fixed summation order every arm uses.
const NEIGHBORS: [(i64, i64); 8] =
    [(-1, -1), (0, -1), (1, -1), (-1, 0), (1, 0), (-1, 1), (0, 1), (1, 1)];

/// Workload configuration.
#[derive(Debug, Clone, Copy)]
pub struct MdConfig {
    /// Grid cells per row (torus in x).
    pub cells_x: usize,
    /// Grid rows (torus in y); must be divisible by `threads` so each
    /// thread owns one contiguous row band.
    pub cells_y: usize,
    /// UPC threads / socket ranks.
    pub threads: usize,
    /// Particle count.
    pub particles: usize,
    /// Time steps.
    pub steps: usize,
    /// Rebuild period K: the plan is recompiled before steps 1, K+1,
    /// 2K+1, … (K = 1 rebuilds every step).
    pub rebuild_every: usize,
    /// PRNG seed for initial positions, velocities, and the initial field.
    pub seed: u64,
}

impl MdConfig {
    /// The CI-sized configuration (`repro mdlite --quick`).
    pub fn quick() -> MdConfig {
        MdConfig {
            cells_x: 24,
            cells_y: 24,
            threads: 4,
            particles: 96,
            steps: 48,
            rebuild_every: 16,
            seed: 0x4d44,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.cells_x < 3 || self.cells_y < 3 {
            return Err("mdlite grid must be at least 3×3".into());
        }
        if self.threads == 0 || self.cells_y % self.threads != 0 {
            return Err(format!(
                "cells_y ({}) must be a positive multiple of threads ({})",
                self.cells_y, self.threads
            ));
        }
        if self.particles == 0 || self.steps == 0 || self.rebuild_every == 0 {
            return Err("particles, steps, and rebuild_every must be positive".into());
        }
        let n = self.cells_x.checked_mul(self.cells_y).ok_or("grid too large")?;
        if n > u32::MAX as usize {
            return Err("grid too large for u32 plan indices".into());
        }
        Ok(())
    }

    /// Total cells.
    pub fn n(&self) -> usize {
        self.cells_x * self.cells_y
    }

    /// Cells per thread band.
    pub fn band(&self) -> usize {
        (self.cells_y / self.threads) * self.cells_x
    }

    /// The block-cyclic layout of the field: one full row band per thread,
    /// so thread `t` owns global cells `[t·band, (t+1)·band)`.
    pub fn layout(&self) -> Layout {
        Layout::new(self.n(), self.band(), self.threads)
    }
}

/// Closed-form particle trajectories in fixed-point torus coordinates.
#[derive(Debug, Clone)]
struct Particles {
    px: Vec<i64>,
    py: Vec<i64>,
    vx: Vec<i64>,
    vy: Vec<i64>,
}

impl Particles {
    fn new(cfg: &MdConfig) -> Particles {
        let mut rng = Rng::new(cfg.seed ^ 0x70617274);
        let (ex, ey) = (cfg.cells_x as i64 * RES, cfg.cells_y as i64 * RES);
        let mut p = Particles {
            px: Vec::with_capacity(cfg.particles),
            py: Vec::with_capacity(cfg.particles),
            vx: Vec::with_capacity(cfg.particles),
            vy: Vec::with_capacity(cfg.particles),
        };
        for _ in 0..cfg.particles {
            p.px.push(rng.usize_in(0, ex as usize) as i64);
            p.py.push(rng.usize_in(0, ey as usize) as i64);
            // Velocities in [-5, 5] fixed-point units per step: under one
            // cell per step, so patterns drift incrementally.
            p.vx.push(rng.usize_in(0, 11) as i64 - 5);
            p.vy.push(rng.usize_in(0, 11) as i64 - 5);
        }
        p
    }

    /// The cell particle `i` occupies at pattern step `s` — pure integer
    /// arithmetic, identical on every thread and rank.
    fn cell_at(&self, cfg: &MdConfig, i: usize, s: usize) -> usize {
        let (ex, ey) = (cfg.cells_x as i64 * RES, cfg.cells_y as i64 * RES);
        let x = (self.px[i] + s as i64 * self.vx[i]).rem_euclid(ex) / RES;
        let y = (self.py[i] + s as i64 * self.vy[i]).rem_euclid(ey) / RES;
        y as usize * cfg.cells_x + x as usize
    }
}

/// Per-cell particle counts at pattern step `s`.
fn occupancy(cfg: &MdConfig, parts: &Particles, s: usize) -> Vec<u32> {
    let mut occ = vec![0u32; cfg.n()];
    for i in 0..cfg.particles {
        occ[parts.cell_at(cfg, i, s)] += 1;
    }
    occ
}

/// The 8 torus neighbors of `cell`, in the fixed order of [`NEIGHBORS`].
fn neighbors8(cfg: &MdConfig, cell: usize) -> [usize; 8] {
    let (w, h) = (cfg.cells_x as i64, cfg.cells_y as i64);
    let (x, y) = ((cell % cfg.cells_x) as i64, (cell / cfg.cells_x) as i64);
    let mut out = [0usize; 8];
    for (k, (dx, dy)) in NEIGHBORS.iter().enumerate() {
        let nx = (x + dx).rem_euclid(w);
        let ny = (y + dy).rem_euclid(h);
        out[k] = (ny * w + nx) as usize;
    }
    out
}

/// Per-receiver needs map: sender → sorted unique global indices. This is
/// the bookkeeping form the incremental lifecycle diffs pair-by-pair.
type Needs = Vec<BTreeMap<u32, Vec<u32>>>;

/// The remote 8-neighbor halo of every occupied owned cell, per receiver.
fn needs_at(cfg: &MdConfig, layout: &Layout, occ: &[u32]) -> Needs {
    let band = cfg.band();
    let mut needs: Needs = vec![BTreeMap::new(); cfg.threads];
    for (t, per) in needs.iter_mut().enumerate() {
        let mut seen: BTreeMap<u32, std::collections::BTreeSet<u32>> = BTreeMap::new();
        for l in 0..band {
            let g = t * band + l;
            if occ[g] == 0 {
                continue;
            }
            for nb in neighbors8(cfg, g) {
                let owner = layout.owner_of_index(nb);
                if owner != t {
                    seen.entry(owner as u32).or_default().insert(nb as u32);
                }
            }
        }
        for (owner, idxs) in seen {
            per.insert(owner, idxs.into_iter().collect());
        }
    }
    needs
}

/// Compile a condensed gather plan from a needs map (the full-recompile
/// oracle path).
fn compile(layout: &Layout, needs: &Needs) -> ExchangePlan {
    let mut recv: Vec<Vec<(u32, u32)>> = Vec::with_capacity(needs.len());
    for per in needs {
        let mut list = Vec::new();
        for (&s, idxs) in per {
            for &i in idxs {
                list.push((s, i));
            }
        }
        recv.push(list);
    }
    CommPlan::from_recv_needs(layout, &recv).into()
}

/// Pair-by-pair diff of two needs maps into gather patches: one patch per
/// (receiver, sender) pair whose index list changed, an empty patch for a
/// pair that disappeared. Cost is proportional to the pairs *present*, not
/// to the plan — the incremental lifecycle never walks unchanged arenas.
fn patches_between(layout: &Layout, old: &Needs, new: &Needs) -> Vec<GatherPatch> {
    let mut patches = Vec::new();
    for (t, (before_map, after_map)) in old.iter().zip(new.iter()).enumerate() {
        let senders: std::collections::BTreeSet<u32> =
            before_map.keys().chain(after_map.keys()).copied().collect();
        for s in senders {
            let before = before_map.get(&s);
            let after = after_map.get(&s);
            if before == after {
                continue;
            }
            let indices = after.cloned().unwrap_or_default();
            let local_src: Vec<u32> = indices
                .iter()
                .map(|&i| layout.local_offset_of_index(i as usize) as u32)
                .collect();
            patches.push(GatherPatch { receiver: t as u32, sender: s, indices, local_src });
        }
    }
    patches
}

/// One run's outcome: the final global field plus plan-lifecycle
/// statistics.
#[derive(Debug, Clone)]
pub struct MdResult {
    /// Final field, stitched to global order.
    pub phi: Vec<f64>,
    /// Fingerprint of the last plan generation.
    pub plan_fp: u64,
    /// Delta-chain fingerprint `fp(gen N) = hash(fp(gen N−1), delta)`.
    /// Seeded with generation 0's plan fingerprint; only advanced by
    /// [`Lifecycle::Incremental`].
    pub chain_fp: u64,
    /// Plan generations compiled (including generation 0).
    pub generations: u64,
    /// Dirty (receiver, sender) pairs across all incremental rebuilds.
    pub dirty_pairs: usize,
    /// Replacement values shipped across all incremental rebuilds.
    pub patch_values: usize,
    /// Live (receiver, sender) pairs in the final plan.
    pub plan_pairs: usize,
    /// Gathered remote values in the final plan (per step).
    pub plan_values: usize,
    /// Total gather payload over the run (8 bytes per staged value per
    /// step, identical across arms by construction).
    pub bytes: u64,
}

impl MdResult {
    /// Order-sensitive FNV over the final field bits — the cheap bitwise
    /// identity check the harness rows report.
    pub fn checksum(&self) -> u64 {
        let mut h = Fnv64::new();
        for &v in &self.phi {
            h.write_u64(v.to_bits());
        }
        h.finish()
    }
}

/// Initial field: one global PRNG stream, sliced into bands by each arm.
fn init_field(cfg: &MdConfig) -> Vec<f64> {
    let mut rng = Rng::new(cfg.seed ^ 0x6669656c64);
    (0..cfg.n()).map(|_| rng.f64_in(0.0, 1.0)).collect()
}

/// Count live (receiver, sender) pairs in a gather plan.
fn plan_pairs(plan: &ExchangePlan) -> usize {
    let p = plan.as_gather().expect("mdlite plans are gather plans");
    (0..p.threads()).map(|t| p.recv_msgs(t).count()).sum()
}

/// Pack thread `t`'s outgoing messages from its local band.
fn pack_thread(plan: &CommPlan, t: usize, local: &[f64]) -> Vec<(usize, Vec<f64>)> {
    plan.send_msgs(t)
        .map(|m| (m.start, m.local_src.iter().map(|&o| local[o as usize]).collect()))
        .collect()
}

/// One thread's compute for one step: refresh the workspace (own band +
/// plan scatters), then relax every owned cell. The workspace write
/// history is identical in every arm, so stale neighbor reads between
/// rebuilds are deterministic.
#[allow(clippy::too_many_arguments)]
fn compute_thread(
    cfg: &MdConfig,
    plan: &CommPlan,
    t: usize,
    occ: &[u32],
    staged: &[f64],
    phi_t: &[f64],
    ws_t: &mut [f64],
    phin_t: &mut [f64],
) {
    let band = cfg.band();
    let base = t * band;
    ws_t[base..base + band].copy_from_slice(phi_t);
    for m in plan.recv_msgs(t) {
        for (k, &g) in m.indices.iter().enumerate() {
            ws_t[g as usize] = staged[m.start + k];
        }
    }
    for l in 0..band {
        let g = base + l;
        let mut nsum = 0.0f64;
        for j in neighbors8(cfg, g) {
            nsum += ws_t[j];
        }
        phin_t[l] = 0.7 * ws_t[g] + 0.0375 * nsum + 0.05 * f64::from(occ[g]);
    }
}

/// Advance the plan at a rebuild boundary. Returns the new plan; updates
/// the chain fingerprint and lifecycle statistics in place.
#[allow(clippy::too_many_arguments)]
fn advance_plan(
    layout: &Layout,
    lifecycle: Lifecycle,
    threads: usize,
    current: Option<ExchangePlan>,
    prev_needs: &Needs,
    needs: &Needs,
    chain: &mut u64,
    dirty_pairs: &mut usize,
    patch_values: &mut usize,
) -> Result<ExchangePlan, String> {
    let scratch = compile(layout, needs);
    match (current, lifecycle) {
        (None, _) => {
            *chain = scratch.fingerprint();
            Ok(scratch)
        }
        (Some(_), Lifecycle::FullRecompile) => Ok(scratch),
        (Some(p), Lifecycle::Incremental) => {
            let patches = patches_between(layout, prev_needs, needs);
            let delta = PlanDelta::from_gather_patches(threads, p.fingerprint(), patches)?;
            *dirty_pairs += delta.dirty_pairs();
            *patch_values += delta.patch_values();
            let applied = p.apply_delta(&delta)?;
            if applied.fingerprint() != scratch.fingerprint() {
                return Err(format!(
                    "incremental rebuild diverged from the oracle: {:#018x} vs {:#018x}",
                    applied.fingerprint(),
                    scratch.fingerprint()
                ));
            }
            *chain = chain_fingerprint(*chain, &delta);
            Ok(applied)
        }
    }
}

/// Run mdlite in process on either engine. `Engine::Sequential` replays
/// every thread on the caller; `Engine::Parallel` runs the pack and
/// compute phases on scoped threads over disjoint bands. Both produce
/// bitwise-identical fields.
pub fn run(cfg: &MdConfig, engine: Engine, lifecycle: Lifecycle) -> Result<MdResult, String> {
    cfg.validate()?;
    let layout = cfg.layout();
    let (threads, n, band) = (cfg.threads, cfg.n(), cfg.band());
    let parts = Particles::new(cfg);
    let global0 = init_field(cfg);
    let mut phi: Vec<Vec<f64>> =
        (0..threads).map(|t| global0[t * band..(t + 1) * band].to_vec()).collect();
    let mut phin = phi.clone();
    let mut ws: Vec<Vec<f64>> = vec![vec![0.0; n]; threads];
    let mut plan: Option<ExchangePlan> = None;
    let mut prev_needs: Needs = vec![BTreeMap::new(); threads];
    let (mut chain, mut generations) = (0u64, 0u64);
    let (mut dirty_pairs, mut patch_values) = (0usize, 0usize);
    let mut bytes = 0u64;
    let mut staged: Vec<f64> = Vec::new();
    for s in 1..=cfg.steps {
        let occ = occupancy(cfg, &parts, s - 1);
        if (s - 1) % cfg.rebuild_every == 0 {
            let needs = needs_at(cfg, &layout, &occ);
            plan = Some(advance_plan(
                &layout,
                lifecycle,
                threads,
                plan.take(),
                &prev_needs,
                &needs,
                &mut chain,
                &mut dirty_pairs,
                &mut patch_values,
            )?);
            generations += 1;
            prev_needs = needs;
        }
        let gather = plan.as_ref().unwrap().as_gather().expect("gather plan");
        staged.clear();
        staged.resize(gather.total_values(), 0.0);
        let packed: Vec<Vec<(usize, Vec<f64>)>> = match engine {
            Engine::Sequential => (0..threads).map(|t| pack_thread(gather, t, &phi[t])).collect(),
            Engine::Parallel => std::thread::scope(|sc| {
                let handles: Vec<_> = (0..threads)
                    .map(|t| {
                        let phi = &phi;
                        sc.spawn(move || pack_thread(gather, t, &phi[t]))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            }),
        };
        for per in &packed {
            for (start, vals) in per {
                staged[*start..*start + vals.len()].copy_from_slice(vals);
            }
        }
        match engine {
            Engine::Sequential => {
                for (t, (ws_t, phin_t)) in ws.iter_mut().zip(phin.iter_mut()).enumerate() {
                    compute_thread(cfg, gather, t, &occ, &staged, &phi[t], ws_t, phin_t);
                }
            }
            Engine::Parallel => std::thread::scope(|sc| {
                for (t, (ws_t, phin_t)) in ws.iter_mut().zip(phin.iter_mut()).enumerate() {
                    let (phi, occ, staged) = (&phi, &occ, &staged);
                    sc.spawn(move || {
                        compute_thread(cfg, gather, t, occ, staged, &phi[t], ws_t, phin_t);
                    });
                }
            }),
        }
        bytes += (gather.total_values() * 8) as u64;
        std::mem::swap(&mut phi, &mut phin);
    }
    let plan = plan.unwrap();
    Ok(MdResult {
        phi: phi.concat(),
        plan_fp: plan.fingerprint(),
        chain_fp: chain,
        generations,
        dirty_pairs,
        patch_values,
        plan_pairs: plan_pairs(&plan),
        plan_values: plan.total_values(),
        bytes,
    })
}

/// Run mdlite across `cfg.threads` socket ranks on a loopback mesh. Under
/// [`Lifecycle::Incremental`], rank 0 is the plan coordinator: at every
/// rebuild boundary it diffs the needs, ships the [`PlanDelta`] to every
/// peer as a `KIND_DELTA` frame, and all ranks patch their plan copy and
/// reshape the live transport with
/// [`SocketTransport::install_plan`] — no teardown, no full-plan
/// reshipping. The swap is race-free because every rank installs
/// generation g+1 only after draining all of generation g's epochs, and
/// early frames from fast senders park in the mailbox until then.
///
/// The protocol runs without acks: the socket arena is private and
/// `publish` serializes frames at call time, so slot reuse never races and
/// run-ahead only parks frames in mailboxes.
pub fn run_socket(
    cfg: &MdConfig,
    lifecycle: Lifecycle,
    deadline: Option<Duration>,
) -> Result<MdResult, String> {
    cfg.validate()?;
    let mesh = loopback_mesh(cfg.threads).map_err(|e| format!("loopback mesh: {e}"))?;
    let results: Vec<Result<(Vec<f64>, MdResult), String>> = std::thread::scope(|sc| {
        let handles: Vec<_> = mesh
            .into_iter()
            .enumerate()
            .map(|(rank, row)| sc.spawn(move || run_rank(cfg, lifecycle, rank, row, deadline)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("mdlite rank panicked")).collect()
    });
    let mut phi = Vec::with_capacity(cfg.n());
    let mut agg: Option<MdResult> = None;
    for r in results {
        let (band, stats) = r?;
        phi.extend_from_slice(&band);
        match &agg {
            None => agg = Some(stats),
            Some(a) => {
                let same = a.plan_fp == stats.plan_fp
                    && a.chain_fp == stats.chain_fp
                    && a.generations == stats.generations;
                if !same {
                    return Err("socket ranks diverged on the plan lifecycle".into());
                }
            }
        }
    }
    let mut out = agg.expect("at least one rank");
    out.phi = phi;
    Ok(out)
}

/// One socket rank's run.
fn run_rank(
    cfg: &MdConfig,
    lifecycle: Lifecycle,
    rank: usize,
    row: MeshStreams,
    deadline: Option<Duration>,
) -> Result<(Vec<f64>, MdResult), String> {
    let layout = cfg.layout();
    let (threads, n, band) = (cfg.threads, cfg.n(), cfg.band());
    let parts = Particles::new(cfg);
    let global0 = init_field(cfg);
    let mut phi: Vec<f64> = global0[rank * band..(rank + 1) * band].to_vec();
    let mut phin = phi.clone();
    let mut ws = vec![0.0f64; n];
    // Generation 0 is compiled locally by every rank (the needs are
    // closed-form); only *deltas* ever cross the wire.
    let occ0 = occupancy(cfg, &parts, 0);
    let needs0 = needs_at(cfg, &layout, &occ0);
    let mut plan = compile(&layout, &needs0);
    let mut prev_needs = needs0;
    let mut chain = plan.fingerprint();
    let mut generations = 1u64;
    let (mut dirty_pairs, mut patch_values) = (0usize, 0usize);
    let mut bytes = 0u64;
    let mut transport = SocketTransport::new(rank, &plan, row, deadline)
        .map_err(|e| format!("rank {rank} transport: {e}"))?;
    for s in 1..=cfg.steps {
        let occ = occupancy(cfg, &parts, s - 1);
        if s > 1 && (s - 1) % cfg.rebuild_every == 0 {
            let needs = needs_at(cfg, &layout, &occ);
            let scratch = compile(&layout, &needs);
            match lifecycle {
                Lifecycle::FullRecompile => plan = scratch,
                Lifecycle::Incremental => {
                    let delta = if rank == 0 {
                        let patches = patches_between(&layout, &prev_needs, &needs);
                        let d =
                            PlanDelta::from_gather_patches(threads, plan.fingerprint(), patches)?;
                        for peer in 1..threads {
                            transport.send_delta(peer, generations, &d)?;
                        }
                        d
                    } else {
                        let d = transport.recv_delta(0, generations)?;
                        if d.base_fingerprint() != plan.fingerprint() {
                            return Err(format!(
                                "rank {rank}: shipped delta targets plan {:#018x}, have {:#018x}",
                                d.base_fingerprint(),
                                plan.fingerprint()
                            ));
                        }
                        d
                    };
                    dirty_pairs += delta.dirty_pairs();
                    patch_values += delta.patch_values();
                    let applied = plan.apply_delta(&delta)?;
                    if applied.fingerprint() != scratch.fingerprint() {
                        return Err(format!(
                            "rank {rank}: incremental rebuild diverged from the oracle"
                        ));
                    }
                    chain = chain_fingerprint(chain, &delta);
                    plan = applied;
                }
            }
            generations += 1;
            prev_needs = needs;
            transport.install_plan(&plan);
        }
        let gather = plan.as_gather().expect("gather plan");
        let epoch = s as u64;
        for m in gather.send_msgs(rank) {
            let slot = transport.send_slot(epoch, m.range());
            for (k, &o) in m.local_src.iter().enumerate() {
                slot[k] = phi[o as usize];
            }
        }
        transport.publish(epoch).map_err(|e| e.to_string())?;
        let senders: std::collections::BTreeSet<usize> =
            gather.recv_msgs(rank).map(|m| m.peer as usize).collect();
        for &peer in &senders {
            transport.wait_for_epoch(peer, epoch).map_err(|e| e.to_string())?;
        }
        ws[rank * band..(rank + 1) * band].copy_from_slice(&phi);
        for m in gather.recv_msgs(rank) {
            let slot = transport.recv_slot(epoch, m.range());
            for (k, &g) in m.indices.iter().enumerate() {
                ws[g as usize] = slot[k];
            }
        }
        for l in 0..band {
            let g = rank * band + l;
            let mut nsum = 0.0f64;
            for j in neighbors8(cfg, g) {
                nsum += ws[j];
            }
            phin[l] = 0.7 * ws[g] + 0.0375 * nsum + 0.05 * f64::from(occ[g]);
        }
        bytes += (gather.total_values() * 8) as u64;
        std::mem::swap(&mut phi, &mut phin);
    }
    let stats = MdResult {
        phi: Vec::new(),
        plan_fp: plan.fingerprint(),
        chain_fp: chain,
        generations,
        dirty_pairs,
        patch_values,
        plan_pairs: plan_pairs(&plan),
        plan_values: plan.total_values(),
        bytes,
    };
    Ok((phi, stats))
}

/// The from-scratch gather plan for the particle occupancy at pattern step
/// `step` — the oracle both rebuild arms compare against, exposed so the
/// harness can time a full compile without re-deriving workload internals.
pub fn plan_at(cfg: &MdConfig, step: usize) -> Result<ExchangePlan, String> {
    cfg.validate()?;
    let layout = cfg.layout();
    let parts = Particles::new(cfg);
    let occ = occupancy(cfg, &parts, step);
    Ok(compile(&layout, &needs_at(cfg, &layout, &occ)))
}

/// The [`PlanDelta`] taking the step-`s0` plan to the step-`s1` plan —
/// exposed so the harness can time delta construction and
/// [`ExchangePlan::apply_delta`] against a full compile when calibrating
/// [`RebuildModel`](crate::model::RebuildModel).
pub fn delta_between(cfg: &MdConfig, s0: usize, s1: usize) -> Result<PlanDelta, String> {
    cfg.validate()?;
    let layout = cfg.layout();
    let parts = Particles::new(cfg);
    let n0 = needs_at(cfg, &layout, &occupancy(cfg, &parts, s0));
    let n1 = needs_at(cfg, &layout, &occupancy(cfg, &parts, s1));
    let base = compile(&layout, &n0);
    let patches = patches_between(&layout, &n0, &n1);
    PlanDelta::from_gather_patches(cfg.threads, base.fingerprint(), patches)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> MdConfig {
        MdConfig {
            cells_x: 12,
            cells_y: 12,
            threads: 3,
            particles: 30,
            steps: 20,
            rebuild_every: 4,
            seed: 7,
        }
    }

    #[test]
    fn config_validation_rejects_bad_shapes() {
        let mut c = tiny();
        c.cells_y = 13; // not divisible by 3 threads
        assert!(c.validate().is_err());
        c = tiny();
        c.rebuild_every = 0;
        assert!(c.validate().is_err());
        assert!(tiny().validate().is_ok());
    }

    #[test]
    fn trajectories_are_closed_form_and_torus_wrapped() {
        let cfg = tiny();
        let p = Particles::new(&cfg);
        let mut moved = false;
        for i in 0..cfg.particles {
            for s in [0usize, 1, 5, 1000] {
                assert!(p.cell_at(&cfg, i, s) < cfg.n());
            }
            // One full fixed-point torus period in both axes returns every
            // particle to its start cell, whatever its velocity.
            let period = cfg.cells_x * cfg.cells_y * RES as usize;
            assert_eq!(p.cell_at(&cfg, i, 0), p.cell_at(&cfg, i, period));
            moved |= p.cell_at(&cfg, i, 0) != p.cell_at(&cfg, i, 7);
        }
        assert!(moved, "some particle must change cells");
    }

    #[test]
    fn incremental_matches_oracle_bitwise_sequential() {
        let cfg = tiny();
        let oracle = run(&cfg, Engine::Sequential, Lifecycle::FullRecompile).unwrap();
        let incr = run(&cfg, Engine::Sequential, Lifecycle::Incremental).unwrap();
        assert_eq!(oracle.phi, incr.phi, "field must be bitwise identical");
        assert_eq!(oracle.plan_fp, incr.plan_fp);
        assert_eq!(oracle.generations, incr.generations);
        assert_eq!(oracle.bytes, incr.bytes);
        assert!(incr.generations > 1, "workload must actually rebuild");
        assert!(incr.dirty_pairs > 0, "pattern must actually drift");
    }

    #[test]
    fn parallel_engine_matches_sequential_bitwise() {
        let cfg = tiny();
        for lc in [Lifecycle::FullRecompile, Lifecycle::Incremental] {
            let seq = run(&cfg, Engine::Sequential, lc).unwrap();
            let par = run(&cfg, Engine::Parallel, lc).unwrap();
            assert_eq!(seq.phi, par.phi, "{}", lc.name());
            assert_eq!(seq.checksum(), par.checksum());
            assert_eq!(seq.chain_fp, par.chain_fp);
        }
    }

    #[test]
    fn rebuild_every_step_stays_consistent() {
        let mut cfg = tiny();
        cfg.rebuild_every = 1;
        cfg.steps = 8;
        let oracle = run(&cfg, Engine::Sequential, Lifecycle::FullRecompile).unwrap();
        let incr = run(&cfg, Engine::Sequential, Lifecycle::Incremental).unwrap();
        assert_eq!(oracle.phi, incr.phi);
        assert_eq!(incr.generations, 8);
    }

    #[test]
    fn socket_arm_matches_in_process_bitwise() {
        let mut cfg = tiny();
        cfg.steps = 12;
        let deadline = Some(Duration::from_secs(20));
        let inproc = run(&cfg, Engine::Sequential, Lifecycle::Incremental).unwrap();
        let socket = run_socket(&cfg, Lifecycle::Incremental, deadline).unwrap();
        assert_eq!(inproc.phi, socket.phi, "socket arm must be bitwise identical");
        assert_eq!(inproc.plan_fp, socket.plan_fp);
        assert_eq!(inproc.chain_fp, socket.chain_fp, "delta chain must match over the wire");
        assert_eq!(inproc.generations, socket.generations);
    }

    #[test]
    fn calibration_hooks_agree_with_the_lifecycle() {
        let cfg = tiny();
        let base = plan_at(&cfg, 0).unwrap();
        let delta = delta_between(&cfg, 0, 4).unwrap();
        assert_eq!(delta.base_fingerprint(), base.fingerprint());
        let applied = base.apply_delta(&delta).unwrap();
        assert_eq!(applied.fingerprint(), plan_at(&cfg, 4).unwrap().fingerprint());
    }

    #[test]
    fn checksum_is_field_sensitive() {
        let cfg = tiny();
        let a = run(&cfg, Engine::Sequential, Lifecycle::FullRecompile).unwrap();
        let mut cfg2 = cfg;
        cfg2.seed ^= 1;
        let b = run(&cfg2, Engine::Sequential, Lifecycle::FullRecompile).unwrap();
        assert_ne!(a.checksum(), b.checksum());
    }
}
