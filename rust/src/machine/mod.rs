//! Hardware characteristic parameters and cost primitives (paper §5.2.2,
//! §6.2).
//!
//! The paper's whole modeling philosophy is that a target system is
//! represented by exactly four numbers:
//!
//! * `W_thread_private` — per-thread bandwidth to private memory
//!   (multi-threaded STREAM / threads-per-node),
//! * `W_node_remote`    — per-node interconnect bandwidth for contiguous
//!   remote transfers (MPI ping-pong),
//! * `τ`                — latency of one individual remote memory operation
//!   (the Listing-6 microbenchmark),
//! * the last-level cache line size.
//!
//! [`HwParams::abel`] carries the measured Abel-cluster values from §6.2,
//! which both the closed-form models (`model`) and the cluster simulator
//! (`sim`) consume.

mod naive;

pub use naive::{NaiveOverheads, PTR_ACCESSES_PER_ROW};

/// Size of one `double` (the paper's `sizeof(double)`).
pub const SIZEOF_DOUBLE: usize = 8;
/// Size of one `int` column index (the paper's `sizeof(int)`).
pub const SIZEOF_INT: usize = 4;

/// The four hardware characteristic parameters (plus threads/node, needed to
/// derive the per-thread STREAM share).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwParams {
    /// Per-thread private-memory bandwidth `W_thread_private`, bytes/s.
    pub w_thread_private: f64,
    /// Per-node remote (interconnect) bandwidth `W_node_remote`, bytes/s.
    pub w_node_remote: f64,
    /// Latency of an individual remote memory operation `τ`, seconds.
    pub tau: f64,
    /// Last-level cache line size, bytes.
    pub cache_line: usize,
    /// Threads per node the above `w_thread_private` was derived for.
    pub threads_per_node: usize,
}

impl HwParams {
    /// The Abel cluster (§6.2): STREAM 75 GB/s per 16-thread node, FDR
    /// InfiniBand ping-pong ≈ 6 GB/s, τ = 3.4 µs, 64 B cache lines.
    pub fn abel() -> HwParams {
        HwParams {
            w_thread_private: 75.0e9 / 16.0,
            w_node_remote: 6.0e9,
            tau: 3.4e-6,
            cache_line: 64,
            threads_per_node: 16,
        }
    }

    /// Rescale the per-thread private bandwidth for a different thread count
    /// on the node. STREAM bandwidth saturates, so this is *not* linear; we
    /// interpolate between a 1-thread point and the saturated aggregate
    /// using a saturation curve `W_node(t) = A · t / (t + k)`, calibrated so
    /// `W_node(16) = 75 GB/s` and `W_node(1) = 5.4 GB/s`. The 1-thread point
    /// is backed out of the paper's own Table 2: UPCv1 at one thread took
    /// 270.40 s / 1000 iterations over n = 6,810,586 rows of 216 B eq.(6)
    /// traffic → 6.8e6·216/0.2704 ≈ 5.4 GB/s effective single-thread
    /// bandwidth (§5.1 warns the raw single-threaded STREAM figure cannot
    /// be used directly — this is the UPC-effective value).
    pub fn with_threads_per_node(&self, threads: usize) -> HwParams {
        assert!(threads > 0);
        let w_sat = self.w_thread_private * self.threads_per_node as f64; // aggregate at calibration point
        // Recover the curve's asymptote A from the two calibration points:
        //   A·1/(1+k) = w1,  A·t_cal/(t_cal+k) = w_sat
        let w1 = 5.4e9_f64.min(w_sat); // 1-thread share (see doc comment)
        let t_cal = self.threads_per_node as f64;
        // From the two equations: A = w1·(1+k), w_sat = A·t/(t+k)
        //  → w1·(1+k)·t_cal = w_sat·(t_cal+k)
        //  → k·(w1·t_cal − w_sat) = w_sat·t_cal − w1·t_cal
        let denom = w1 * t_cal - w_sat;
        let k = if denom.abs() < 1e-3 {
            0.0
        } else {
            (w_sat * t_cal - w1 * t_cal) / denom
        };
        let k = k.max(0.0);
        let a = w1 * (1.0 + k);
        let t = threads as f64;
        let w_node = a * t / (t + k);
        HwParams {
            w_thread_private: w_node / t,
            threads_per_node: threads,
            ..*self
        }
    }

    /// Time for one thread to stream `bytes` through private memory
    /// (`bytes / W_thread_private`).
    #[inline]
    pub fn t_private_stream(&self, bytes: f64) -> f64 {
        bytes / self.w_thread_private
    }

    /// Eq. (8), local flavour: one element moved as part of a contiguous
    /// local inter-thread transfer.
    #[inline]
    pub fn t_cntg_local(&self, elem_bytes: usize) -> f64 {
        elem_bytes as f64 / self.w_thread_private
    }

    /// Eq. (8), remote flavour: one element moved as part of a contiguous
    /// remote transfer.
    #[inline]
    pub fn t_cntg_remote(&self, elem_bytes: usize) -> f64 {
        elem_bytes as f64 / self.w_node_remote
    }

    /// Eq. (9): one *individual* local inter-thread operation pays a full
    /// cache line from the owner's memory.
    #[inline]
    pub fn t_indv_local(&self) -> f64 {
        self.cache_line as f64 / self.w_thread_private
    }

    /// One *individual* remote operation costs the latency τ (§5.2.2).
    #[inline]
    pub fn t_indv_remote(&self) -> f64 {
        self.tau
    }

    /// A contiguous remote message of `bytes`: τ start-up + bandwidth term
    /// (as used inside eqs. (11) and (13)).
    #[inline]
    pub fn t_remote_message(&self, bytes: f64) -> f64 {
        self.tau + bytes / self.w_node_remote
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abel_values() {
        let hw = HwParams::abel();
        assert!((hw.w_thread_private - 4.6875e9).abs() < 1.0);
        assert_eq!(hw.cache_line, 64);
        // τ dominates short messages
        assert!(hw.t_remote_message(8.0) > hw.tau);
        assert!(hw.t_indv_remote() == 3.4e-6);
    }

    #[test]
    fn cost_primitives_scale() {
        let hw = HwParams::abel();
        assert!((hw.t_private_stream(75.0e9 / 16.0) - 1.0).abs() < 1e-12);
        assert!((hw.t_cntg_remote(8) - 8.0 / 6.0e9).abs() < 1e-18);
        // individual local = cache line / W
        assert!((hw.t_indv_local() - 64.0 / (75.0e9 / 16.0)).abs() < 1e-18);
    }

    #[test]
    fn thread_rescaling_saturates() {
        let hw = HwParams::abel();
        let w_node_16 = hw.w_thread_private * 16.0;
        let hw8 = hw.with_threads_per_node(8);
        let w_node_8 = hw8.w_thread_private * 8.0;
        let hw1 = hw.with_threads_per_node(1);
        let w_node_1 = hw1.w_thread_private;
        // Node bandwidth grows with threads but sublinearly.
        assert!(w_node_1 < w_node_8 && w_node_8 < w_node_16 + 1.0);
        assert!(w_node_8 > w_node_16 / 2.0, "saturation implies >linear share at low t");
        // Calibration point reproduced exactly.
        let hw16 = hw.with_threads_per_node(16);
        assert!((hw16.w_thread_private - hw.w_thread_private).abs() / hw.w_thread_private < 1e-9);
        // 1-thread share ≈ 5.4 GB/s (backed out of the paper's Table 2).
        assert!((w_node_1 - 5.4e9).abs() / 5.4e9 < 1e-9);
    }
}
