//! Hardware characteristic parameters and cost primitives (paper §5.2.2,
//! §6.2).
//!
//! The paper's whole modeling philosophy is that a target system is
//! represented by exactly four numbers:
//!
//! * `W_thread_private` — per-thread bandwidth to private memory
//!   (multi-threaded STREAM / threads-per-node),
//! * `W_node_remote`    — per-node interconnect bandwidth for contiguous
//!   remote transfers (MPI ping-pong),
//! * `τ`                — latency of one individual remote memory operation
//!   (the Listing-6 microbenchmark),
//! * the last-level cache line size.
//!
//! The kernel tier adds one measured refinement, [`HwParams::w_pack`] —
//! the bandwidth the indexed gather/scatter pack kernels actually sustain,
//! defaulting to `W_thread_private` (which recovers eq. (19) verbatim).
//!
//! [`HwParams::abel`] carries the measured Abel-cluster values from §6.2,
//! which both the closed-form models (`model`) and the cluster simulator
//! (`sim`) consume. [`Calibration`] measures the same four parameters on
//! the real host (`repro calibrate`), and [`HwSource`] selects between the
//! paper constants, a fresh host calibration, and a saved calibration file
//! (`--hw abel|host|file:<path>`).

mod calibrate;
mod naive;

pub use calibrate::{Calibration, HwSource};
pub use naive::{NaiveOverheads, PTR_ACCESSES_PER_ROW};

/// Size of one `double` (the paper's `sizeof(double)`).
pub const SIZEOF_DOUBLE: usize = 8;
/// Size of one `int` column index (the paper's `sizeof(int)`).
pub const SIZEOF_INT: usize = 4;

/// The four hardware characteristic parameters (plus threads/node, needed to
/// derive the per-thread STREAM share).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwParams {
    /// Per-thread private-memory bandwidth `W_thread_private`, bytes/s.
    pub w_thread_private: f64,
    /// Per-node remote (interconnect) bandwidth `W_node_remote`, bytes/s.
    pub w_node_remote: f64,
    /// Latency of an individual remote memory operation `τ`, seconds.
    pub tau: f64,
    /// Last-level cache line size, bytes.
    pub cache_line: usize,
    /// Threads per node the above `w_thread_private` was derived for.
    pub threads_per_node: usize,
    /// Effective aggregate bandwidth with a *single* thread on the node
    /// (`W_node(1)`), bytes/s — the second calibration point of the
    /// saturation curve in [`HwParams::with_threads_per_node`]. For Abel
    /// this is backed out of the paper's Table 2 (see that method's doc);
    /// host calibrations measure it directly with a 1-thread STREAM pass.
    pub w_node_single: f64,
    /// Pack/unpack bandwidth through a compiled index list, bytes/s — what
    /// the kernel-tier gather/scatter
    /// ([`kernels`](crate::engine::kernels)) sustains, as measured by
    /// [`pack_bandwidth_host`](crate::microbench::pack_bandwidth_host).
    /// The paper's eq. (19) charges pack/unpack at `W_thread_private`; on
    /// hosts where indexed access does not reach streaming bandwidth this
    /// separates the two. Abel (and calibration files predating this
    /// field) default it to `w_thread_private`, which reproduces eq. (19)
    /// exactly.
    pub w_pack: f64,
}

impl HwParams {
    /// The Abel cluster (§6.2): STREAM 75 GB/s per 16-thread node, FDR
    /// InfiniBand ping-pong ≈ 6 GB/s, τ = 3.4 µs, 64 B cache lines.
    pub fn abel() -> HwParams {
        HwParams {
            w_thread_private: 75.0e9 / 16.0,
            w_node_remote: 6.0e9,
            tau: 3.4e-6,
            cache_line: 64,
            threads_per_node: 16,
            w_node_single: 5.4e9,
            w_pack: 75.0e9 / 16.0,
        }
    }

    /// Rescale the per-thread private bandwidth for a different thread count
    /// on the node. STREAM bandwidth saturates, so this is *not* linear; we
    /// interpolate between the 1-thread point [`HwParams::w_node_single`]
    /// and the saturated aggregate using a saturation curve
    /// `W_node(t) = A · t / (t + k)`, calibrated so `W_node(t_cal)` equals
    /// the aggregate at the calibration thread count (Abel: 75 GB/s at 16)
    /// and `W_node(1) = w_node_single` (Abel: 5.4 GB/s, backed out of the
    /// paper's own Table 2: UPCv1 at one thread took 270.40 s / 1000
    /// iterations over n = 6,810,586 rows of 216 B eq.(6) traffic →
    /// 6.8e6·216/0.2704 ≈ 5.4 GB/s effective single-thread bandwidth; §5.1
    /// warns the raw single-threaded STREAM figure cannot be used directly —
    /// this is the UPC-effective value).
    ///
    /// The saturation curve only fits when scaling is *sublinear* between
    /// the two calibration points (`w1 · t_cal > w_sat` ⇔ `k > 0`). At or
    /// past the linear regime the fit degenerates — a clamped `k = 0` would
    /// freeze the aggregate at `w1`, i.e. *decreasing* per-thread bandwidth
    /// and an aggregate far below the measured `w_sat` — so we fall back to
    /// linear scaling through the calibration point, which keeps `W_node(t)`
    /// monotone non-decreasing and exact at `t_cal` in both regimes.
    pub fn with_threads_per_node(&self, threads: usize) -> HwParams {
        assert!(threads > 0);
        let w_sat = self.w_thread_private * self.threads_per_node as f64; // aggregate at calibration point
        let w1 = self.w_node_single.min(w_sat); // 1-thread aggregate (see doc comment)
        let t_cal = self.threads_per_node as f64;
        let t = threads as f64;
        // Recover the curve's parameters from the two calibration points:
        //   A·1/(1+k) = w1,  A·t_cal/(t_cal+k) = w_sat
        //  → k·(w1·t_cal − w_sat) = w_sat·t_cal − w1·t_cal
        let denom = w1 * t_cal - w_sat;
        // Regime guard is *relative* to the bandwidth scale: the old
        // `denom.abs() < 1e-3` compared bytes/s against 1e-3 and never
        // fired. `denom ≤ ~0` means linear-or-better scaling (including the
        // t_cal = 1 case, where the curve is unconstrained).
        let w_node = if denom <= 1e-6 * w_sat {
            // Linear regime: constant per-thread share w_sat / t_cal.
            w_sat * t / t_cal
        } else {
            let k = (w_sat - w1) * t_cal / denom; // > 0 here since w1 ≤ w_sat
            let a = w1 * (1.0 + k);
            a * t / (t + k)
        };
        HwParams {
            w_thread_private: w_node / t,
            threads_per_node: threads,
            ..*self
        }
    }

    /// Time for one thread to stream `bytes` through private memory
    /// (`bytes / W_thread_private`).
    #[inline]
    pub fn t_private_stream(&self, bytes: f64) -> f64 {
        bytes / self.w_thread_private
    }

    /// Time for one thread to move `bytes` through the indexed
    /// gather/scatter pack kernels (`bytes / w_pack`) — the eq. (19) pack
    /// term with the measured pack bandwidth in place of the STREAM
    /// figure.
    #[inline]
    pub fn t_pack_stream(&self, bytes: f64) -> f64 {
        bytes / self.w_pack
    }

    /// Eq. (8), local flavour: one element moved as part of a contiguous
    /// local inter-thread transfer.
    #[inline]
    pub fn t_cntg_local(&self, elem_bytes: usize) -> f64 {
        elem_bytes as f64 / self.w_thread_private
    }

    /// Eq. (8), remote flavour: one element moved as part of a contiguous
    /// remote transfer.
    #[inline]
    pub fn t_cntg_remote(&self, elem_bytes: usize) -> f64 {
        elem_bytes as f64 / self.w_node_remote
    }

    /// Eq. (9): one *individual* local inter-thread operation pays a full
    /// cache line from the owner's memory.
    #[inline]
    pub fn t_indv_local(&self) -> f64 {
        self.cache_line as f64 / self.w_thread_private
    }

    /// One *individual* remote operation costs the latency τ (§5.2.2).
    #[inline]
    pub fn t_indv_remote(&self) -> f64 {
        self.tau
    }

    /// A contiguous remote message of `bytes`: τ start-up + bandwidth term
    /// (as used inside eqs. (11) and (13)).
    #[inline]
    pub fn t_remote_message(&self, bytes: f64) -> f64 {
        self.tau + bytes / self.w_node_remote
    }
}

/// Which transport a model evaluation is parameterized for.
///
/// The paper's models take the interconnect's τ and `W_node_remote` as
/// opaque measured inputs — which is exactly what makes them portable
/// across transports: a different memory world is the *same* model with a
/// different (τ, bandwidth) pair. [`TransportModel::apply`] performs that
/// substitution on an [`HwParams`], leaving every private-memory and
/// cache-line term untouched.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TransportModel {
    /// The in-process shared-memory world: the calibrated parameters
    /// already describe it, so `apply` is the identity.
    Inproc,
    /// The socket world: substitute the ping-pong probe's per-message
    /// latency for τ and its streaming bandwidth for `W_node_remote`
    /// (see [`socket_probe`](crate::transport::socket_probe)).
    Socket {
        /// One-way per-message latency, seconds.
        latency: f64,
        /// Streaming bandwidth, bytes/s.
        bandwidth: f64,
    },
}

impl TransportModel {
    /// The in-process transport (identity substitution).
    pub fn inproc() -> TransportModel {
        TransportModel::Inproc
    }

    /// A socket transport measured at `latency` seconds per message and
    /// `bandwidth` bytes/s.
    pub fn socket(latency: f64, bandwidth: f64) -> TransportModel {
        assert!(
            latency > 0.0 && bandwidth > 0.0,
            "socket transport model needs positive latency and bandwidth"
        );
        TransportModel::Socket { latency, bandwidth }
    }

    /// Short label for tables and JSON reports.
    pub fn label(&self) -> &'static str {
        match self {
            TransportModel::Inproc => "inproc",
            TransportModel::Socket { .. } => "socket",
        }
    }

    /// Substitute this transport's remote terms into `hw`.
    pub fn apply(&self, hw: &HwParams) -> HwParams {
        match *self {
            TransportModel::Inproc => *hw,
            TransportModel::Socket { latency, bandwidth } => {
                HwParams { tau: latency, w_node_remote: bandwidth, ..*hw }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_model_substitutes_remote_terms() {
        let hw = HwParams::abel();
        assert_eq!(TransportModel::inproc().apply(&hw), hw);
        let tm = TransportModel::socket(25.0e-6, 1.2e9);
        let sub = tm.apply(&hw);
        assert_eq!(sub.tau, 25.0e-6);
        assert_eq!(sub.w_node_remote, 1.2e9);
        // Private-memory and cache terms are untouched.
        assert_eq!(sub.w_thread_private, hw.w_thread_private);
        assert_eq!(sub.cache_line, hw.cache_line);
        assert_eq!(sub.w_node_single, hw.w_node_single);
        assert_eq!(sub.w_pack, hw.w_pack);
        assert_eq!(tm.label(), "socket");
        assert_eq!(TransportModel::inproc().label(), "inproc");
    }

    #[test]
    fn abel_values() {
        let hw = HwParams::abel();
        assert!((hw.w_thread_private - 4.6875e9).abs() < 1.0);
        assert_eq!(hw.cache_line, 64);
        // τ dominates short messages
        assert!(hw.t_remote_message(8.0) > hw.tau);
        assert!(hw.t_indv_remote() == 3.4e-6);
    }

    #[test]
    fn cost_primitives_scale() {
        let hw = HwParams::abel();
        assert!((hw.t_private_stream(75.0e9 / 16.0) - 1.0).abs() < 1e-12);
        assert!((hw.t_cntg_remote(8) - 8.0 / 6.0e9).abs() < 1e-18);
        // individual local = cache line / W
        assert!((hw.t_indv_local() - 64.0 / (75.0e9 / 16.0)).abs() < 1e-18);
    }

    #[test]
    fn thread_rescaling_saturates() {
        let hw = HwParams::abel();
        let w_node_16 = hw.w_thread_private * 16.0;
        let hw8 = hw.with_threads_per_node(8);
        let w_node_8 = hw8.w_thread_private * 8.0;
        let hw1 = hw.with_threads_per_node(1);
        let w_node_1 = hw1.w_thread_private;
        // Node bandwidth grows with threads but sublinearly.
        assert!(w_node_1 < w_node_8 && w_node_8 < w_node_16 + 1.0);
        assert!(w_node_8 > w_node_16 / 2.0, "saturation implies >linear share at low t");
        // Calibration point reproduced exactly.
        let hw16 = hw.with_threads_per_node(16);
        assert!((hw16.w_thread_private - hw.w_thread_private).abs() / hw.w_thread_private < 1e-9);
        // 1-thread share ≈ 5.4 GB/s (backed out of the paper's Table 2).
        assert!((w_node_1 - 5.4e9).abs() / 5.4e9 < 1e-9);
    }

    /// Aggregate node bandwidth must never *decrease* as threads grow, in
    /// every calibration regime (the old negative-`k` clamp violated this
    /// whenever `w1·t_cal ≤ w_sat`).
    #[test]
    fn w_node_monotone_non_decreasing() {
        let cases = [
            HwParams::abel(), // sublinear regime (saturation curve)
            // Linear regime: single-thread point is exactly the per-thread
            // share of the aggregate.
            HwParams { w_node_single: 75.0e9 / 16.0, ..HwParams::abel() },
            // Degenerate "superlinear" measurement: w1 above the per-thread
            // share times t_cal (w1·t_cal > w_sat is impossible here since
            // w1 is clamped to w_sat, but the raw input can claim it).
            HwParams { w_node_single: 100.0e9, ..HwParams::abel() },
            // Calibrated at a single thread (t_cal = 1): the curve is
            // unconstrained, so scaling must fall back to linear.
            HwParams {
                w_thread_private: 8.0e9,
                threads_per_node: 1,
                w_node_single: 8.0e9,
                ..HwParams::abel()
            },
        ];
        for (i, hw) in cases.iter().enumerate() {
            let mut prev = 0.0f64;
            for t in 1..=64usize {
                let w_node = hw.with_threads_per_node(t).w_thread_private * t as f64;
                assert!(
                    w_node + 1e-3 >= prev,
                    "case {i}: W_node({t}) = {w_node} < W_node({}) = {prev}",
                    t - 1
                );
                assert!(w_node.is_finite() && w_node > 0.0, "case {i} t={t}: {w_node}");
                prev = w_node;
            }
            // Calibration point is reproduced exactly in every regime.
            let t_cal = hw.threads_per_node;
            let back = hw.with_threads_per_node(t_cal);
            let w_sat = hw.w_thread_private * t_cal as f64;
            let w_back = back.w_thread_private * t_cal as f64;
            assert!((w_back - w_sat).abs() / w_sat < 1e-9, "case {i}");
        }
    }

    #[test]
    fn linear_regime_scales_linearly() {
        // 1-thread calibration: W_node(t) must extrapolate linearly.
        let hw = HwParams {
            w_thread_private: 8.0e9,
            threads_per_node: 1,
            w_node_single: 8.0e9,
            ..HwParams::abel()
        };
        let hw4 = hw.with_threads_per_node(4);
        assert!((hw4.w_thread_private - 8.0e9).abs() / 8.0e9 < 1e-9);
    }
}
