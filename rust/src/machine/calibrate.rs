//! Host calibration: measure the four hardware characteristic parameters on
//! the machine actually running the binary.
//!
//! The paper's modeling philosophy (§5.2.2) is that a target system is
//! represented by four easily obtainable numbers. [`HwParams::abel`] carries
//! the paper's measured Abel values; [`Calibration`] measures the same four
//! numbers with the real-host microbenchmarks in [`crate::microbench`], so
//! the eqs. (5)–(18) models can predict the wall-clock behaviour of the
//! parallel engine on *this* machine (`repro calibrate` / `repro validate`).
//!
//! A calibration is measured once and persisted as JSON (`util::json`), so
//! later runs can load it with `--hw file:<path>` instead of re-measuring.

use super::HwParams;
use crate::microbench;
use crate::util::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Version tag written into calibration files; bump when the schema changes.
const CALIBRATION_VERSION: f64 = 1.0;

/// A measured host calibration: the raw microbenchmark readings plus the
/// [`HwParams`] derived from them. τ, the cache line size and the thread
/// count live only inside `hw` (they are the measurement, not derived), so
/// a loaded file cannot carry two disagreeing copies.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// The derived model parameters (what every consumer wants).
    pub hw: HwParams,
    /// Aggregate all-thread STREAM triad bandwidth, bytes/s.
    pub stream_node: f64,
    /// Single-thread STREAM triad bandwidth, bytes/s (the raw reading
    /// behind the clamped `hw.w_node_single`).
    pub stream_single: f64,
    /// Cross-thread contiguous-copy bandwidth, bytes/s (ping-pong analog).
    pub memcpy_cross: f64,
    /// Loopback socket per-message latency, seconds — the τ analog of the
    /// socket transport (`repro launch`). 0.0 when the probe could not run.
    pub socket_latency: f64,
    /// Loopback socket streaming bandwidth, bytes/s — the `W_node_remote`
    /// analog of the socket transport. 0.0 when the probe could not run.
    pub socket_bandwidth: f64,
    /// Whether the quick (reduced working set) profile was used.
    pub quick: bool,
}

impl Calibration {
    /// Run all four host microbenchmarks and derive an [`HwParams`].
    ///
    /// `quick` trims repetitions and sample counts (several × faster,
    /// slightly noisier) while keeping every working set LLC-defeating —
    /// the profile CI and the test suite use. A full measurement takes a
    /// few seconds on an idle machine.
    pub fn measure(quick: bool) -> Calibration {
        let threads = microbench::host_threads();
        // Bandwidth/latency working sets must defeat the LLC, not just the
        // L2, in BOTH profiles — an LLC-resident pass reports cache
        // bandwidth as W and skews every prediction derived from the
        // calibration. STREAM moves 3 × 16 MiB per thread, memcpy 32/64 MiB,
        // and the τ arena (slots × 128 B) is 16/32 MiB; "quick" economizes
        // on repetitions and the τ/cache-line sample counts instead.
        let (stream_elems, memcpy_bytes, tau_slots, tau_ops, line_buf) = if quick {
            (1 << 21, 32 << 20, 1 << 17, 50_000, 4 << 20)
        } else {
            (1 << 21, 64 << 20, 1 << 18, 400_000, 32 << 20)
        };
        let (pack_elems, pack_reps) = if quick { (1 << 20, 3) } else { (1 << 22, 5) };
        let stream_node = microbench::stream_host_threads(threads, stream_elems).bandwidth();
        let stream_single = microbench::stream_host_threads(1, stream_elems).bandwidth();
        let memcpy_cross = microbench::memcpy_cross_thread(memcpy_bytes, 4).bandwidth();
        let pack_bandwidth = microbench::pack_bandwidth_host(pack_elems, pack_reps).bandwidth();
        let tau = microbench::tau_cross_thread(tau_slots, tau_ops);
        let cache_line = microbench::cache_line_host(line_buf);
        // The socket probe is best-effort: a sandbox without loopback
        // listeners must not sink the whole calibration. Zeroed fields mean
        // "not measured" and keep the file loadable either way.
        let (socket_latency, socket_bandwidth) = match crate::transport::socket_probe(quick) {
            Ok(p) => (p.latency, p.bandwidth),
            Err(e) => {
                eprintln!("calibrate: socket probe skipped ({e})");
                (0.0, 0.0)
            }
        };
        let hw = HwParams {
            w_thread_private: stream_node / threads as f64,
            w_node_remote: memcpy_cross,
            tau,
            cache_line,
            threads_per_node: threads,
            // A 1-thread triad can exceed the per-thread share but never the
            // aggregate; clamp against measurement noise.
            w_node_single: stream_single.min(stream_node),
            w_pack: pack_bandwidth,
        };
        Calibration {
            hw,
            stream_node,
            stream_single,
            memcpy_cross,
            socket_latency,
            socket_bandwidth,
            quick,
        }
    }

    /// The socket transport's model parameters, if the probe ran. `None`
    /// means the calibration predates the socket fields or the probe was
    /// skipped; callers should fall back to probing live.
    pub fn socket_model(&self) -> Option<super::TransportModel> {
        (self.socket_latency > 0.0 && self.socket_bandwidth > 0.0)
            .then(|| super::TransportModel::socket(self.socket_latency, self.socket_bandwidth))
    }

    /// Serialize to the JSON document `save`/`load` exchange.
    pub fn to_json(&self) -> Value {
        let mut root = Value::obj();
        root.set("version", Value::Num(CALIBRATION_VERSION));
        root.set("hw", self.hw.to_json());
        root.set("stream_node", Value::Num(self.stream_node));
        root.set("stream_single", Value::Num(self.stream_single));
        root.set("memcpy_cross", Value::Num(self.memcpy_cross));
        root.set("socket_latency", Value::Num(self.socket_latency));
        root.set("socket_bandwidth", Value::Num(self.socket_bandwidth));
        root.set("quick", Value::Bool(self.quick));
        root
    }

    /// Deserialize from the [`Calibration::to_json`] document.
    pub fn from_json(v: &Value) -> Result<Calibration> {
        let num = |obj: &Value, key: &str| -> Result<f64> {
            obj.get(key)
                .and_then(Value::as_f64)
                .ok_or_else(|| anyhow!("calibration JSON missing numeric field '{key}'"))
        };
        let version = num(v, "version")?;
        if version != CALIBRATION_VERSION {
            bail!("calibration file version {version} (this build reads {CALIBRATION_VERSION})");
        }
        let hw_obj = v.get("hw").ok_or_else(|| anyhow!("calibration JSON missing 'hw'"))?;
        let hw = HwParams::from_json(hw_obj)?;
        // The socket fields postdate version 1.0 files; absent means "not
        // measured" (same as a skipped probe), so older files stay loadable.
        let opt = |key: &str| v.get(key).and_then(Value::as_f64).unwrap_or(0.0);
        Ok(Calibration {
            hw,
            stream_node: num(v, "stream_node")?,
            stream_single: num(v, "stream_single")?,
            memcpy_cross: num(v, "memcpy_cross")?,
            socket_latency: opt("socket_latency"),
            socket_bandwidth: opt("socket_bandwidth"),
            quick: matches!(v.get("quick"), Some(Value::Bool(true))),
        })
    }

    /// Write the calibration to `path` as pretty-printed JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing calibration to {}", path.display()))
    }

    /// Load a calibration previously written by [`Calibration::save`].
    pub fn load(path: &Path) -> Result<Calibration> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading calibration from {}", path.display()))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing calibration {}", path.display()))?;
        Calibration::from_json(&v)
    }
}

impl HwParams {
    /// Measure this host's four characteristic parameters (quick profile).
    /// Prefer `repro calibrate` + `--hw file:<path>` when the same
    /// calibration should be reused across runs.
    pub fn calibrate_host() -> HwParams {
        Calibration::measure(true).hw
    }

    /// The single JSON shape for a parameter set — shared by calibration
    /// files and the `BENCH_model.json` report, so the two cannot drift.
    pub fn to_json(&self) -> Value {
        let mut o = Value::obj();
        o.set("w_thread_private", Value::Num(self.w_thread_private));
        o.set("w_node_remote", Value::Num(self.w_node_remote));
        o.set("tau", Value::Num(self.tau));
        o.set("cache_line", Value::Num(self.cache_line as f64));
        o.set("threads_per_node", Value::Num(self.threads_per_node as f64));
        o.set("w_node_single", Value::Num(self.w_node_single));
        o.set("w_pack", Value::Num(self.w_pack));
        o
    }

    /// Inverse of [`HwParams::to_json`]; rejects non-positive parameters.
    pub fn from_json(v: &Value) -> Result<HwParams> {
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Value::as_f64)
                .ok_or_else(|| anyhow!("hw JSON missing numeric field '{key}'"))
        };
        let w_thread_private = num("w_thread_private")?;
        let hw = HwParams {
            w_thread_private,
            w_node_remote: num("w_node_remote")?,
            tau: num("tau")?,
            cache_line: num("cache_line")? as usize,
            threads_per_node: num("threads_per_node")? as usize,
            w_node_single: num("w_node_single")?,
            // The pack-bandwidth key postdates the original schema; files
            // written before it fall back to the eq. (19) assumption
            // (pack at streaming bandwidth), so they stay loadable and
            // predict exactly what they used to.
            w_pack: v
                .get("w_pack")
                .and_then(Value::as_f64)
                .filter(|&w| w > 0.0)
                .unwrap_or(w_thread_private),
        };
        anyhow::ensure!(
            hw.w_thread_private > 0.0
                && hw.w_node_remote > 0.0
                && hw.tau > 0.0
                && hw.cache_line > 0
                && hw.threads_per_node > 0
                && hw.w_node_single > 0.0
                && hw.w_pack > 0.0,
            "hw JSON contains non-positive hardware parameters"
        );
        Ok(hw)
    }
}

/// Where a run's [`HwParams`] come from: the paper's Abel constants, a fresh
/// host calibration, or a saved calibration file. Parsed from the CLI
/// `--hw abel|host|file:<path>` flag (and the `UPCSIM_HW` environment
/// variable for the benches).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HwSource {
    /// The paper's measured Abel-cluster constants (§6.2).
    Abel,
    /// Calibrate the running host now.
    Host,
    /// Load a calibration JSON written by `repro calibrate`.
    File(PathBuf),
}

impl HwSource {
    pub fn parse(s: &str) -> Result<HwSource> {
        if let Some(path) = s.strip_prefix("file:") {
            anyhow::ensure!(!path.is_empty(), "--hw file: needs a path");
            return Ok(HwSource::File(PathBuf::from(path)));
        }
        match s.to_ascii_lowercase().as_str() {
            "abel" => Ok(HwSource::Abel),
            "host" => Ok(HwSource::Host),
            _ => bail!("unknown hw source '{s}' (abel | host | file:<path>)"),
        }
    }

    /// The benches read `UPCSIM_HW` (same grammar as `--hw`, default
    /// `abel`) so a table/figure regeneration can run on either parameter
    /// set without new flags in every bench binary.
    pub fn from_env() -> Result<HwSource> {
        match std::env::var("UPCSIM_HW") {
            Ok(s) if !s.is_empty() => HwSource::parse(&s),
            _ => Ok(HwSource::Abel),
        }
    }

    /// Short label for table titles and JSON reports.
    pub fn label(&self) -> String {
        match self {
            HwSource::Abel => "abel".to_string(),
            HwSource::Host => "host".to_string(),
            HwSource::File(p) => format!("file:{}", p.display()),
        }
    }

    /// Produce the parameters. `quick` selects the reduced measurement
    /// profile when the source is `Host`.
    pub fn resolve(&self, quick: bool) -> Result<HwParams> {
        match self {
            HwSource::Abel => Ok(HwParams::abel()),
            HwSource::Host => Ok(Calibration::measure(quick).hw),
            HwSource::File(p) => Ok(Calibration::load(p)?.hw),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic() -> Calibration {
        Calibration {
            hw: HwParams {
                w_thread_private: 3.25e9,
                w_node_remote: 11.5e9,
                tau: 8.25e-8,
                cache_line: 128,
                threads_per_node: 6,
                w_node_single: 9.0e9,
                w_pack: 2.5e9,
            },
            stream_node: 19.5e9,
            stream_single: 9.0e9,
            memcpy_cross: 11.5e9,
            socket_latency: 30.0e-6,
            socket_bandwidth: 1.5e9,
            quick: true,
        }
    }

    #[test]
    fn socket_fields_are_optional_for_old_files() {
        // A pre-socket calibration file has no socket_* keys: it must still
        // load, with the fields zeroed and no socket model available.
        let mut v = synthetic().to_json();
        v.set("socket_latency", Value::Null);
        v.set("socket_bandwidth", Value::Null);
        let cal = Calibration::from_json(&v).unwrap();
        assert_eq!(cal.socket_latency, 0.0);
        assert_eq!(cal.socket_bandwidth, 0.0);
        assert!(cal.socket_model().is_none());
        // A measured calibration exposes a socket transport model.
        let tm = synthetic().socket_model().unwrap();
        assert_eq!(tm, crate::machine::TransportModel::socket(30.0e-6, 1.5e9));
    }

    #[test]
    fn w_pack_falls_back_to_stream_for_old_files() {
        // A calibration file written before the pack probe has no "w_pack"
        // key inside "hw": it must load with w_pack = w_thread_private,
        // reproducing the original eq. (19) pack terms bit-for-bit.
        let mut v = synthetic().to_json();
        let mut hw_obj = v.get("hw").unwrap().clone();
        hw_obj.set("w_pack", Value::Null);
        v.set("hw", hw_obj);
        let cal = Calibration::from_json(&v).unwrap();
        assert_eq!(cal.hw.w_pack, cal.hw.w_thread_private);
        // A measured file round-trips its own value.
        let back = Calibration::from_json(&synthetic().to_json()).unwrap();
        assert_eq!(back.hw.w_pack, 2.5e9);
    }

    #[test]
    fn json_roundtrip_identical() {
        let cal = synthetic();
        let back = Calibration::from_json(&cal.to_json()).unwrap();
        assert_eq!(cal, back);
        // And through the textual form, exactly as save/load exchange it.
        let text = cal.to_json().pretty();
        let back2 = Calibration::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(cal.hw, back2.hw);
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        let mut v = synthetic().to_json();
        v.set("version", Value::Num(99.0));
        assert!(Calibration::from_json(&v).is_err());
        let mut v = synthetic().to_json();
        v.set("stream_node", Value::Str("fast".into()));
        assert!(Calibration::from_json(&v).is_err());
    }

    #[test]
    fn hw_source_parses() {
        assert_eq!(HwSource::parse("abel").unwrap(), HwSource::Abel);
        assert_eq!(HwSource::parse("HOST").unwrap(), HwSource::Host);
        assert_eq!(
            HwSource::parse("file:cal.json").unwrap(),
            HwSource::File(PathBuf::from("cal.json"))
        );
        assert!(HwSource::parse("file:").is_err());
        assert!(HwSource::parse("cluster9").is_err());
        assert_eq!(HwSource::parse("file:cal.json").unwrap().label(), "file:cal.json");
    }

    #[test]
    fn abel_source_resolves_without_measuring() {
        let hw = HwSource::Abel.resolve(true).unwrap();
        assert_eq!(hw, HwParams::abel());
    }
}
