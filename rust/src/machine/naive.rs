//! UPC language-feature overheads of the *naive* implementation (Listing 2).
//!
//! The paper does not model the naive version (its §5 models start at UPCv1)
//! but measures it in Table 2. To let the simulator reproduce Table 2 we
//! need two constants the paper only describes qualitatively (§4.1):
//!
//! * the per-iteration cost of `upc_forall`'s affinity test — *every* thread
//!   walks the *entire* i-loop and evaluates `upc_threadof(&y[i])`;
//! * the cost of one access through a pointer-to-shared (updating the three
//!   fields: owner id, phase, local address) even when the data is local.
//!
//! We calibrate both from the paper's own Table 2 numbers (Test problem 1,
//! n = 6,810,586, r_nz = 16, 1000 iterations, BLOCKSIZE = 65536):
//!
//! * 1 thread:  naive 895.44 s vs UPCv1 270.40 s → extra 625.0 ms/iter =
//!   `n·(c_forall + P·c_ptr)` with `P = PTR_ACCESSES_PER_ROW`.
//! * 16 threads: naive 106.10 s vs UPCv1 28.80 s → extra 77.3 ms/iter =
//!   `n·c_forall + (n/16)·PTR_ACCESSES_PER_ROW·c_ptr`.
//!
//! Solving the 2×2 system with `PTR_ACCESSES_PER_ROW = 34` gives
//! `c_ptr ≈ 2.5 ns` and `c_forall ≈ 5.9 ns` — both plausible for a
//! Sandy Bridge core (a handful of dependent integer ops each). The values
//! are exposed as data so other calibrations can be swapped in.

/// Pointer-to-shared dereferences per matrix row that UPCv1 *privatizes*
/// (Listing 2 vs Listing 3): 16×`A[i*r_nz+j]` + 16×`J[i*r_nz+j]` + `D[i]` +
/// `y[i]` = 34. Accesses to `x` (direct and indirect) remain through a
/// pointer-to-shared in UPCv1 too, so they cancel in the naive-vs-v1 delta
/// the calibration uses; their off-owner cost is modeled as communication.
pub const PTR_ACCESSES_PER_ROW: f64 = 34.0;

/// Calibrated per-operation overheads of naive UPC codegen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NaiveOverheads {
    /// Cost of one `upc_forall` affinity test (`upc_threadof` + compare), s.
    pub c_forall: f64,
    /// Cost of one access through a pointer-to-shared over and above a
    /// private access (three-field update), s.
    pub c_ptr: f64,
}

impl NaiveOverheads {
    /// Calibration against the paper's Table 2 (see module docs).
    pub fn calibrated() -> NaiveOverheads {
        // Extra time per iteration vs UPCv1, from Table 2 (seconds).
        const N: f64 = 6_810_586.0;
        const EXTRA_1T: f64 = (895.44 - 270.40) / 1000.0; // per iteration
        const EXTRA_16T: f64 = (106.10 - 28.80) / 1000.0;
        // 1 thread : EXTRA_1T  = N·c_forall + N·P·c_ptr
        // 16 threads: EXTRA_16T = N·c_forall + (N/16)·P·c_ptr
        // (upc_forall makes every thread walk all N iterations; only owned
        //  rows execute the body.)
        let p = PTR_ACCESSES_PER_ROW;
        let a1 = EXTRA_1T / N; // c_forall + P·c_ptr
        let a16 = EXTRA_16T / N; // c_forall + (P/16)·c_ptr
        let c_ptr = (a1 - a16) / (p - p / 16.0);
        let c_forall = a1 - p * c_ptr;
        NaiveOverheads { c_forall, c_ptr }
    }
}

impl Default for NaiveOverheads {
    fn default() -> Self {
        Self::calibrated()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_is_plausible() {
        let o = NaiveOverheads::calibrated();
        // Both constants positive, nanosecond scale.
        assert!(o.c_forall > 0.5e-9 && o.c_forall < 50e-9, "c_forall={}", o.c_forall);
        assert!(o.c_ptr > 0.2e-9 && o.c_ptr < 50e-9, "c_ptr={}", o.c_ptr);
    }

    #[test]
    fn calibration_reproduces_table2_endpoints() {
        let o = NaiveOverheads::calibrated();
        let n = 6_810_586.0;
        let p = PTR_ACCESSES_PER_ROW;
        let extra_1t = n * (o.c_forall + p * o.c_ptr) * 1000.0;
        let extra_16t = (n * o.c_forall + n / 16.0 * p * o.c_ptr) * 1000.0;
        assert!((extra_1t - (895.44 - 270.40)).abs() < 0.01, "{extra_1t}");
        assert!((extra_16t - (106.10 - 28.80)).abs() < 0.01, "{extra_16t}");
    }
}
